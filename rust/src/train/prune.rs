//! Magnitude pruning (paper §4.3): after initial training, set weights
//! below threshold δ to zero, keep them at zero, and refine the remaining
//! weights.  The threshold per layer is chosen as the |w| quantile that
//! reaches the requested pruning factor (the paper reports per-network
//! overall factors of 0.72–0.94).

use anyhow::{ensure, Result};

use super::{TrainConfig, Trainer};
use crate::data::Dataset;
use crate::nn::weights::NetworkWeights;

/// Outcome of one prune-retrain cycle.
#[derive(Debug, Clone)]
pub struct PruneReport {
    /// Requested overall pruning factor.
    pub target: f64,
    /// Achieved overall pruning factor (exact, counted on the weights).
    pub achieved: f64,
    /// Per-layer achieved factors `q_prune^(j)`.
    pub per_layer: Vec<f64>,
}

/// |w| quantile threshold for a single layer.
fn magnitude_threshold(weights: &[f32], q: f64) -> f32 {
    if weights.is_empty() || q <= 0.0 {
        return 0.0;
    }
    let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((mags.len() as f64 * q).floor() as usize).min(mags.len() - 1);
    mags[idx]
}

/// Install pruning masks on a trainer at the given overall factor.
/// Per-layer factors equal the overall target (uniform policy); the last
/// (output) layer is pruned at half the rate because it is tiny and
/// disproportionately accuracy-critical — mirroring common practice and
/// the paper's "maximum 1.5 % deviation" objective.
pub fn apply_pruning(trainer: &mut Trainer, target: f64) -> Result<PruneReport> {
    ensure!((0.0..1.0).contains(&target), "pruning factor must be in [0,1)");
    let layers = trainer.weights.len();
    let mut masks = Vec::with_capacity(layers);
    let mut per_layer = Vec::with_capacity(layers);
    let mut zeros_total = 0usize;
    let mut weights_total = 0usize;
    for (l, w) in trainer.weights.iter_mut().enumerate() {
        let q = if l + 1 == layers { target * 0.5 } else { target };
        let delta = magnitude_threshold(&w.data, q);
        let mut mask = vec![true; w.data.len()];
        let mut zeros = 0usize;
        for (v, m) in w.data.iter_mut().zip(mask.iter_mut()) {
            if v.abs() <= delta {
                *v = 0.0;
                *m = false;
                zeros += 1;
            }
        }
        per_layer.push(zeros as f64 / w.data.len() as f64);
        zeros_total += zeros;
        weights_total += w.data.len();
        masks.push(mask);
    }
    trainer.masks = masks;
    Ok(PruneReport {
        target,
        achieved: zeros_total as f64 / weights_total as f64,
        per_layer,
    })
}

/// The full paper pipeline: train → prune to `target` → retrain.
/// Returns the pruned weights and the report.
pub fn train_prune_retrain(
    trainer: &mut Trainer,
    data: &Dataset,
    initial: &TrainConfig,
    target: f64,
    retrain: &TrainConfig,
) -> Result<(NetworkWeights, PruneReport)> {
    trainer.fit(data, initial)?;
    let report = apply_pruning(trainer, target)?;
    trainer.fit(data, retrain)?;
    Ok((trainer.to_weights(), report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::har;
    use crate::nn::spec::NetworkSpec;
    use crate::train::{evaluate_f32, Trainer};

    #[test]
    fn threshold_is_quantile() {
        let w = [0.1f32, -0.2, 0.3, -0.4, 0.5, -0.6, 0.7, -0.8, 0.9, -1.0];
        let t = magnitude_threshold(&w, 0.5);
        assert!((t - 0.6).abs() < 1e-6, "{t}");
        assert_eq!(magnitude_threshold(&w, 0.0), 0.0);
        assert_eq!(magnitude_threshold(&[], 0.5), 0.0);
    }

    #[test]
    fn pruning_reaches_target_factor() {
        let spec = NetworkSpec::new("t", &[561, 32, 6]);
        let mut tr = Trainer::new(spec, 3);
        let report = apply_pruning(&mut tr, 0.9).unwrap();
        // hidden layer prunes at 0.9, output layer at 0.45; overall close
        // to 0.9 because the hidden layer dominates the parameter count
        assert!(report.achieved > 0.85, "{report:?}");
        assert!(report.per_layer[0] >= 0.899 && report.per_layer[0] <= 0.91);
    }

    #[test]
    fn invalid_factor_rejected() {
        let spec = NetworkSpec::new("t", &[10, 5, 2]);
        let mut tr = Trainer::new(spec, 1);
        assert!(apply_pruning(&mut tr, 1.0).is_err());
        assert!(apply_pruning(&mut tr, -0.1).is_err());
    }

    #[test]
    fn retrain_recovers_accuracy() {
        // the paper's core claim: prune hard, retrain, lose little accuracy
        let train = har::generate(700, 11);
        let test = har::generate(250, 12);
        let spec = NetworkSpec::new("t", &[561, 48, 6]);
        let mut tr = Trainer::new(spec, 13);
        let cfg = TrainConfig {
            epochs: 10,
            ..Default::default()
        };
        tr.fit(&train, &cfg).unwrap();
        let base_acc = evaluate_f32(&tr.to_weights(), &test);

        let report = apply_pruning(&mut tr, 0.8).unwrap();
        let pruned_acc_no_retrain = evaluate_f32(&tr.to_weights(), &test);
        tr.fit(
            &train,
            &TrainConfig {
                epochs: 8,
                learning_rate: 0.02,
                ..Default::default()
            },
        )
        .unwrap();
        let retrained_acc = evaluate_f32(&tr.to_weights(), &test);

        assert!(report.achieved > 0.75);
        assert!(
            retrained_acc >= pruned_acc_no_retrain - 0.02,
            "retraining must not hurt: {pruned_acc_no_retrain} -> {retrained_acc}"
        );
        assert!(
            base_acc - retrained_acc < 0.10,
            "accuracy drop too large: {base_acc} -> {retrained_acc}"
        );
        // masks respected: pruned weights still zero after retraining
        let q = tr.to_weights().quantized();
        assert!(q.overall_prune_factor() >= report.achieved - 1e-9);
    }
}
