//! Training substrate: SGD + momentum backprop for fully-connected
//! networks, plus the paper's pruning procedure (§4.3): after initial
//! training, weights with |w| below a threshold δ are set to zero and
//! *kept* at zero while the remaining weights are refined.
//!
//! Training runs in f32 with exact activations; quantization to Q7.8 and
//! PLAN approximation are inference-time effects measured separately
//! (Table 4 bench).  Hidden layers train with ReLU; the output layer
//! trains as softmax cross-entropy (the paper's sigmoid output is applied
//! at inference, which preserves argmax).

pub mod prune;

use anyhow::{ensure, Result};

use crate::data::Dataset;
use crate::nn::spec::NetworkSpec;
use crate::nn::weights::NetworkWeights;
use crate::tensor::{gemm_f32, MatF};
use crate::util::rng::Xoshiro256;

/// Hyperparameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub learning_rate: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Print a line per epoch when true.
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 10,
            batch_size: 32,
            learning_rate: 0.05,
            momentum: 0.9,
            weight_decay: 1e-5,
            seed: 0x5EED,
            verbose: false,
        }
    }
}

/// Per-epoch progress record.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub loss: f64,
    pub train_accuracy: f64,
}

/// Trainer state: weights + momentum buffers (+ optional pruning masks).
pub struct Trainer {
    pub spec: NetworkSpec,
    pub weights: Vec<MatF>,
    velocity: Vec<MatF>,
    /// One mask per layer; `false` = pruned (kept at zero).  Empty until
    /// [`prune::apply_pruning`] installs masks.
    pub masks: Vec<Vec<bool>>,
    rng: Xoshiro256,
}

impl Trainer {
    /// He/Xavier-style init scaled by fan-in (ReLU-friendly).
    pub fn new(spec: NetworkSpec, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let weights: Vec<MatF> = spec
            .weight_shapes()
            .iter()
            .map(|&(o, i)| {
                let scale = (2.0 / i as f64).sqrt();
                MatF::from_vec(
                    o,
                    i,
                    (0..o * i)
                        .map(|_| rng.normal_scaled(0.0, scale) as f32)
                        .collect(),
                )
            })
            .collect();
        let velocity = weights
            .iter()
            .map(|w| MatF::zeros(w.rows, w.cols))
            .collect();
        Self {
            spec,
            weights,
            velocity,
            masks: Vec::new(),
            rng,
        }
    }

    /// Resume from existing weights (used by the prune-retrain loop).
    pub fn from_weights(nw: NetworkWeights, seed: u64) -> Self {
        let velocity = nw
            .weights
            .iter()
            .map(|w| MatF::zeros(w.rows, w.cols))
            .collect();
        Self {
            spec: nw.spec,
            weights: nw.weights,
            velocity,
            masks: Vec::new(),
            rng: Xoshiro256::seed_from_u64(seed),
        }
    }

    pub fn to_weights(&self) -> NetworkWeights {
        NetworkWeights::new(self.spec.clone(), self.weights.clone())
            .expect("trainer shapes are valid by construction")
    }

    /// One epoch of minibatch SGD; returns (mean loss, train accuracy).
    pub fn train_epoch(&mut self, data: &Dataset) -> (f64, f64) {
        let n = data.len();
        let mut order: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut order);
        let bs = 32.min(n).max(1);
        let mut total_loss = 0.0;
        let mut correct = 0usize;
        for chunk in order.chunks(bs) {
            let (loss, c) = self.train_batch(data, chunk, 0.05, 0.9, 1e-5);
            total_loss += loss * chunk.len() as f64;
            correct += c;
        }
        (total_loss / n as f64, correct as f64 / n as f64)
    }

    /// One minibatch step with explicit hyperparameters.
    fn train_batch(
        &mut self,
        data: &Dataset,
        idx: &[usize],
        lr: f32,
        momentum: f32,
        weight_decay: f32,
    ) -> (f64, usize) {
        let bs = idx.len();
        let in_dim = self.spec.inputs();
        let mut x = MatF::zeros(bs, in_dim);
        for (r, &i) in idx.iter().enumerate() {
            x.row_mut(r).copy_from_slice(data.x.row(i));
        }

        // ---- forward, keeping activations; hidden = ReLU, output = logits
        let layers = self.weights.len();
        let mut acts: Vec<MatF> = Vec::with_capacity(layers + 1);
        acts.push(x);
        for (l, w) in self.weights.iter().enumerate() {
            let a = acts.last().unwrap();
            let mut z = MatF::zeros(bs, w.rows);
            gemm_f32(a, w, &mut z);
            if l + 1 < layers {
                for v in z.data.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            acts.push(z);
        }

        // ---- softmax cross-entropy on the logits
        let logits = acts.last().unwrap();
        let classes = logits.cols;
        let mut delta = MatF::zeros(bs, classes); // dL/dz of output layer
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for r in 0..bs {
            let row = logits.row(r);
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f64> = row.iter().map(|&v| f64::from(v - max).exp()).collect();
            let sum: f64 = exps.iter().sum();
            let label = data.y[idx[r]];
            loss -= (exps[label] / sum).max(1e-30).ln();
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == label {
                correct += 1;
            }
            let d = delta.row_mut(r);
            for c in 0..classes {
                d[c] = ((exps[c] / sum) as f32 - if c == label { 1.0 } else { 0.0 })
                    / bs as f32;
            }
        }

        // ---- backward
        let mut grads: Vec<MatF> = Vec::with_capacity(layers);
        let mut cur_delta = delta;
        for l in (0..layers).rev() {
            let a_prev = &acts[l];
            let w = &self.weights[l];
            // grad[o][i] = sum_n delta[n][o] * a_prev[n][i]
            let mut grad = MatF::zeros(w.rows, w.cols);
            for n in 0..bs {
                let dn = cur_delta.row(n);
                let an = a_prev.row(n);
                for o in 0..w.rows {
                    let g = grad.row_mut(o);
                    let d = dn[o];
                    if d != 0.0 {
                        for (gi, &ai) in g.iter_mut().zip(an.iter()) {
                            *gi += d * ai;
                        }
                    }
                }
            }
            grads.push(grad);
            if l > 0 {
                // delta_prev[n][i] = (sum_o delta[n][o] * w[o][i]) * relu'(z_prev)
                let mut prev = MatF::zeros(bs, w.cols);
                for n in 0..bs {
                    let dn = cur_delta.row(n);
                    let pn = prev.row_mut(n);
                    for o in 0..w.rows {
                        let d = dn[o];
                        if d != 0.0 {
                            let wr = w.row(o);
                            for (pi, &wi) in pn.iter_mut().zip(wr.iter()) {
                                *pi += d * wi;
                            }
                        }
                    }
                    // ReLU derivative via the stored activation
                    let zn = acts[l].row(n);
                    for (pi, &zi) in pn.iter_mut().zip(zn.iter()) {
                        if zi <= 0.0 {
                            *pi = 0.0;
                        }
                    }
                }
                cur_delta = prev;
            }
        }
        grads.reverse();

        // ---- SGD + momentum + weight decay, respecting pruning masks
        for (l, grad) in grads.iter().enumerate() {
            let w = &mut self.weights[l];
            let v = &mut self.velocity[l];
            let mask = self.masks.get(l);
            for i in 0..w.data.len() {
                if let Some(m) = mask {
                    if !m[i] {
                        w.data[i] = 0.0;
                        v.data[i] = 0.0;
                        continue;
                    }
                }
                let g = grad.data[i] + weight_decay * w.data[i];
                v.data[i] = momentum * v.data[i] - lr * g;
                w.data[i] += v.data[i];
            }
        }
        let _ = (lr, momentum, weight_decay);
        (loss / bs as f64, correct)
    }

    /// Full training run.
    pub fn fit(&mut self, data: &Dataset, cfg: &TrainConfig) -> Result<Vec<EpochStats>> {
        ensure!(
            data.features() == self.spec.inputs(),
            "dataset features {} != network inputs {}",
            data.features(),
            self.spec.inputs()
        );
        ensure!(
            data.num_classes == self.spec.outputs(),
            "dataset classes {} != network outputs {}",
            data.num_classes,
            self.spec.outputs()
        );
        let mut stats = Vec::with_capacity(cfg.epochs);
        let n = data.len();
        let mut order: Vec<usize> = (0..n).collect();
        for epoch in 0..cfg.epochs {
            self.rng.shuffle(&mut order);
            let mut total_loss = 0.0;
            let mut correct = 0usize;
            for chunk in order.chunks(cfg.batch_size.max(1)) {
                let (loss, c) = self.train_batch(
                    data,
                    chunk,
                    cfg.learning_rate,
                    cfg.momentum,
                    cfg.weight_decay,
                );
                total_loss += loss * chunk.len() as f64;
                correct += c;
            }
            let s = EpochStats {
                epoch,
                loss: total_loss / n as f64,
                train_accuracy: correct as f64 / n as f64,
            };
            if cfg.verbose {
                eprintln!(
                    "epoch {:>3}  loss {:.4}  train-acc {:.3}",
                    s.epoch, s.loss, s.train_accuracy
                );
            }
            stats.push(s);
        }
        Ok(stats)
    }
}

/// Test-set accuracy of f32 weights (exact activations).
pub fn evaluate_f32(nw: &NetworkWeights, data: &Dataset) -> f64 {
    let y = crate::nn::forward::forward_f32(&nw.spec, &nw.weights, &data.x)
        .expect("shape checked");
    let preds = crate::nn::forward::argmax_rows_f32(&y);
    let correct = preds
        .iter()
        .zip(data.y.iter())
        .filter(|(p, y)| p == y)
        .count();
    correct as f64 / data.len().max(1) as f64
}

/// Test-set accuracy of the quantized Q7.8 network.
///
/// Classification is scored on the *identity-requantized logits* of the
/// output layer rather than its sigmoid image: sigmoid is monotone, so in
/// exact arithmetic the argmax is identical, but the Q7.8 output grid
/// collapses every |z| ≥ 5 to exactly 1.0 (the PLAN saturation segment),
/// and softmax-trained networks with confident logits would lose accuracy
/// to index-order tie-breaking — a resolution artifact of the output
/// *encoding*, not of the datapath the paper evaluates.  Hidden layers run
/// the full hardware path (Q7.8 wrapping MACs, ReLU requantization).
pub fn evaluate_q(nw: &NetworkWeights, data: &Dataset) -> f64 {
    let mut spec = nw.spec.clone();
    if let Some(last) = spec.activations.last_mut() {
        *last = crate::nn::spec::Activation::Identity;
    }
    let wq = nw.weights.iter().map(crate::nn::quantize_matrix).collect();
    let qnet = crate::nn::forward::QNetwork::new(spec, wq).expect("shapes validated");
    let xq = crate::nn::quantize_matrix(&data.x);
    let y = crate::nn::forward::forward_q(&qnet, &xq).expect("shape checked");
    let preds = crate::nn::forward::argmax_rows(&y);
    let correct = preds
        .iter()
        .zip(data.y.iter())
        .filter(|(p, y)| p == y)
        .count();
    correct as f64 / data.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{har, mnist};
    use crate::nn::spec::NetworkSpec;

    #[test]
    fn loss_decreases_on_small_mnist() {
        let data = mnist::generate(300, 1);
        let spec = NetworkSpec::new("tiny", &[784, 32, 10]);
        let mut t = Trainer::new(spec, 7);
        let cfg = TrainConfig {
            epochs: 5,
            ..Default::default()
        };
        let stats = t.fit(&data, &cfg).unwrap();
        assert!(
            stats.last().unwrap().loss < stats.first().unwrap().loss,
            "{:?}",
            stats
        );
    }

    #[test]
    fn learns_har_to_decent_accuracy() {
        let data = har::generate(600, 2);
        let test = har::generate(200, 3);
        let spec = NetworkSpec::new("tiny-har", &[561, 48, 6]);
        let mut t = Trainer::new(spec, 8);
        let cfg = TrainConfig {
            epochs: 12,
            learning_rate: 0.05,
            ..Default::default()
        };
        t.fit(&data, &cfg).unwrap();
        let acc = evaluate_f32(&t.to_weights(), &test);
        assert!(acc > 0.8, "accuracy {acc}");
    }

    #[test]
    fn quantized_eval_close_to_f32() {
        let data = har::generate(400, 4);
        let test = har::generate(150, 5);
        let spec = NetworkSpec::new("tiny-har", &[561, 32, 6]);
        let mut t = Trainer::new(spec, 9);
        t.fit(
            &data,
            &TrainConfig {
                epochs: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let nw = t.to_weights();
        let f = evaluate_f32(&nw, &test);
        let q = evaluate_q(&nw, &test);
        assert!((f - q).abs() < 0.1, "f32 {f} vs q {q}");
    }

    #[test]
    fn fit_validates_dataset_shape() {
        let data = mnist::generate(10, 1);
        let spec = NetworkSpec::new("bad", &[100, 10, 10]);
        let mut t = Trainer::new(spec, 1);
        assert!(t.fit(&data, &TrainConfig::default()).is_err());
    }

    #[test]
    fn masked_weights_stay_zero_through_training() {
        let data = har::generate(120, 6);
        let spec = NetworkSpec::new("tiny-har", &[561, 16, 6]);
        let mut t = Trainer::new(spec, 10);
        // mask half of layer 0
        let len = t.weights[0].data.len();
        let mut mask = vec![true; len];
        for m in mask.iter_mut().take(len / 2) {
            *m = false;
        }
        for (i, keep) in mask.iter().enumerate() {
            if !keep {
                t.weights[0].data[i] = 0.0;
            }
        }
        t.masks = vec![mask.clone(), vec![true; t.weights[1].data.len()]];
        t.fit(
            &data,
            &TrainConfig {
                epochs: 2,
                ..Default::default()
            },
        )
        .unwrap();
        for (i, keep) in mask.iter().enumerate() {
            if !keep {
                assert_eq!(t.weights[0].data[i], 0.0);
            }
        }
    }
}
