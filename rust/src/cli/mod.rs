//! Command-line argument parsing (clap is not in the offline crate set).
//! Small positional + `--flag value` parser with typed accessors and a
//! generated usage string.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed arguments: positionals + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

/// Declarative flag spec for usage rendering and validation.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

pub fn parse(args: &[String], specs: &[FlagSpec]) -> Result<Args> {
    let mut out = Args::default();
    let mut it = args.iter().peekable();
    while let Some(arg) = it.next() {
        if let Some(name) = arg.strip_prefix("--") {
            let (name, inline) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            let Some(spec) = specs.iter().find(|s| s.name == name) else {
                bail!("unknown flag --{name}\n{}", usage(specs));
            };
            if spec.takes_value {
                let value = match inline {
                    Some(v) => v,
                    None => it
                        .next()
                        .with_context(|| format!("--{name} needs a value"))?
                        .clone(),
                };
                out.flags.insert(name.to_string(), value);
            } else {
                if inline.is_some() {
                    bail!("--{name} takes no value");
                }
                out.switches.push(name.to_string());
            }
        } else {
            out.positionals.push(arg.clone());
        }
    }
    Ok(out)
}

pub fn usage(specs: &[FlagSpec]) -> String {
    let mut s = String::from("flags:\n");
    for f in specs {
        s.push_str(&format!(
            "  --{}{}  {}\n",
            f.name,
            if f.takes_value { " <value>" } else { "" },
            f.help
        ));
    }
    s
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name}: bad integer {v:?}")),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name}: bad number {v:?}")),
            None => Ok(default),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec {
                name: "batch",
                takes_value: true,
                help: "batch size",
            },
            FlagSpec {
                name: "verbose",
                takes_value: false,
                help: "chatty",
            },
        ]
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_positionals_flags_switches() {
        let a = parse(&sv(&["bench", "table2", "--batch", "16", "--verbose"]), &specs()).unwrap();
        assert_eq!(a.positionals, vec!["bench", "table2"]);
        assert_eq!(a.get("batch"), Some("16"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("batch", 1).unwrap(), 16);
    }

    #[test]
    fn inline_equals_form() {
        let a = parse(&sv(&["--batch=8"]), &specs()).unwrap();
        assert_eq!(a.get_usize("batch", 1).unwrap(), 8);
    }

    #[test]
    fn unknown_flag_rejected_with_usage() {
        let err = parse(&sv(&["--nope"]), &specs()).unwrap_err().to_string();
        assert!(err.contains("unknown flag") && err.contains("--batch"));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&sv(&["--batch"]), &specs()).is_err());
        assert!(parse(&sv(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get_usize("batch", 4).unwrap(), 4);
        assert_eq!(a.get_or("missing", "x"), "x");
        assert!((a.get_f64("missing", 1.5).unwrap() - 1.5).abs() < 1e-12);
    }
}
