//! Cache-aware roofline models of the paper's software platforms (Table 1).
//!
//! The paper's software baseline is BLAS sgemv per layer, sample after
//! sample.  Across consecutive samples the *whole network's* weights must
//! stay in the last-level cache to be reused; the deciding quantity is
//! therefore total weight bytes vs LLC capacity:
//!
//! * network fits   → compute-bound at the core's sustained SIMD rate,
//! * network spills → the non-resident fraction streams from DRAM every
//!   sample and the run goes memory-bound (the paper's "tables are turned
//!   for matrices of the deep learning era").
//!
//! Residency is modelled as `min(1, LLC / total_bytes)` (LRU steady state).
//! Threads speed up compute sub-linearly (BLAS gemv), never memory;
//! hyper-threads beyond the physical cores *hurt* slightly — exactly the
//! pattern of Table 2's thread sweeps.
//!
//! Coefficients are sustained-rate calibrations against four cells of
//! Table 2 (ARM MNIST-4, i7-5600U MNIST-4, i7-4790 MNIST-4 and HAR-6,
//! single-thread); all other 34 software cells are then predictions whose
//! errors EXPERIMENTS.md reports.

use crate::nn::spec::NetworkSpec;

/// A software platform model (one row-group of Table 2).
#[derive(Debug, Clone)]
pub struct MachineModel {
    pub name: &'static str,
    /// Sustained f32 FLOP/s of one core running BLAS gemv.
    pub flops_per_core: f64,
    /// Physical cores (hyper-threads beyond this degrade).
    pub physical_cores: usize,
    /// Marginal speedup per extra physical core (gemv scales poorly).
    pub thread_eff: f64,
    /// Multiplicative penalty once SMT threads are used.
    pub ht_penalty: f64,
    /// Last-level cache bytes available to the weight working set.
    pub llc_bytes: f64,
    /// Sustained DRAM streaming bandwidth for gemv access patterns (B/s).
    pub dram_bw: f64,
    /// Fixed per-layer overhead (BLAS call + scheduling), seconds.
    pub layer_overhead: f64,
}

/// ARM Cortex-A9 @667 MHz (ZedBoard PS, bare-metal, no NEON in the
/// measured configuration — the paper notes a NEON fixed-point version
/// would be ~4× faster and still lose by an order of magnitude).
pub const ARM_CORTEX_A9: MachineModel = MachineModel {
    name: "ARM Cortex-A9",
    flops_per_core: 0.16e9,
    physical_cores: 2, // bare-metal uses one
    thread_eff: 0.0,
    ht_penalty: 1.0,
    llc_bytes: 0.4e6,
    dram_bw: 0.6e9,
    layer_overhead: 8e-6,
};

/// Intel i7-5600U (Broadwell mobile, 2C/4T, single-channel DDR3).
pub const I7_5600U: MachineModel = MachineModel {
    name: "Intel i7-5600U",
    flops_per_core: 9.0e9, // ~18 % of 51 GFLOP/s AVX2-FMA peak
    physical_cores: 2,
    thread_eff: 0.35,
    ht_penalty: 0.90,
    llc_bytes: 4.0e6,
    dram_bw: 7.0e9, // gemv-strided share of 12.8 GB/s peak
    layer_overhead: 2e-6,
};

/// Intel i7-4790 (Haswell desktop, 4C/8T, dual-channel DDR3).
pub const I7_4790: MachineModel = MachineModel {
    name: "Intel i7-4790",
    flops_per_core: 22.0e9, // ~34 % of 64 GFLOP/s AVX2-FMA peak
    physical_cores: 4,
    thread_eff: 0.45,
    ht_penalty: 0.92,
    llc_bytes: 8.0e6,
    dram_bw: 10.0e9, // gemv-strided share of 25.6 GB/s peak
    layer_overhead: 1.5e-6,
};

impl MachineModel {
    /// Effective compute speedup at a thread count.
    pub fn speedup(&self, threads: usize) -> f64 {
        let threads = threads.max(1);
        let phys = threads.min(self.physical_cores);
        let s = 1.0 + self.thread_eff * (phys - 1) as f64;
        if threads > self.physical_cores {
            s * self.ht_penalty
        } else {
            s
        }
    }

    /// Steady-state LLC residency of the network's weights.
    pub fn residency(&self, spec: &NetworkSpec) -> f64 {
        let bytes = (spec.num_parameters() * 4) as f64;
        (self.llc_bytes / bytes).min(1.0)
    }

    /// Seconds per sample for a whole network.
    pub fn network_time(&self, spec: &NetworkSpec, threads: usize) -> f64 {
        let params = spec.num_parameters() as f64;
        let flops = 2.0 * params;
        let bytes = 4.0 * params;
        let t_compute = flops / (self.flops_per_core * self.speedup(threads));
        let dram_bytes = bytes * (1.0 - self.residency(spec));
        let t_memory = dram_bytes / self.dram_bw;
        t_compute.max(t_memory)
            + self.layer_overhead * (spec.num_layers() - 1) as f64
    }

    /// Whether the full weight set is cache-resident (the paper's fast/
    /// slow regime boundary).
    pub fn cache_resident(&self, spec: &NetworkSpec) -> bool {
        self.residency(spec) >= 1.0
    }
}

/// The thread counts Table 2 sweeps per machine.
pub fn table2_thread_sweep(name: &str) -> Vec<usize> {
    match name {
        "ARM Cortex-A9" => vec![1],
        "Intel i7-5600U" => vec![1, 2, 4],
        "Intel i7-4790" => vec![1, 4, 8],
        _ => vec![1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::spec::{har_4, har_6, mnist_4, mnist_8};

    /// Paper Table 2 software cells (ms/sample) for shape checks.
    fn paper_ms(machine: &str, net: &str, threads: usize) -> f64 {
        match (machine, net, threads) {
            ("arm", "mnist4", 1) => 16.151,
            ("arm", "mnist8", 1) => 48.603,
            ("arm", "har6", 1) => 70.240,
            ("5600u", "mnist4", 1) => 0.285,
            ("5600u", "mnist8", 1) => 1.603,
            ("5600u", "har4", 1) => 0.223,
            ("5600u", "har6", 1) => 2.246,
            ("4790", "mnist4", 1) => 0.118,
            ("4790", "mnist8", 1) => 0.917,
            ("4790", "har6", 1) => 1.406,
            _ => unreachable!(),
        }
    }

    fn model_ms(m: &MachineModel, spec: &NetworkSpec, threads: usize) -> f64 {
        m.network_time(spec, threads) * 1e3
    }

    #[test]
    fn single_thread_cells_within_2x_of_paper() {
        let cases: Vec<(&MachineModel, NetworkSpec, &str, &str)> = vec![
            (&ARM_CORTEX_A9, mnist_4(), "arm", "mnist4"),
            (&ARM_CORTEX_A9, mnist_8(), "arm", "mnist8"),
            (&ARM_CORTEX_A9, har_6(), "arm", "har6"),
            (&I7_5600U, mnist_4(), "5600u", "mnist4"),
            (&I7_5600U, mnist_8(), "5600u", "mnist8"),
            (&I7_5600U, har_4(), "5600u", "har4"),
            (&I7_5600U, har_6(), "5600u", "har6"),
            (&I7_4790, mnist_4(), "4790", "mnist4"),
            (&I7_4790, mnist_8(), "4790", "mnist8"),
            (&I7_4790, har_6(), "4790", "har6"),
        ];
        for (m, spec, mn, nn) in cases {
            let got = model_ms(m, &spec, 1);
            let want = paper_ms(mn, nn, 1);
            let ratio = got / want;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{mn}/{nn}: model {got:.3} ms vs paper {want:.3} ms (ratio {ratio:.2})"
            );
        }
    }

    #[test]
    fn cache_residency_regimes_match_paper() {
        // 4-layer nets resident on the desktop, deep nets on nobody
        assert!(I7_4790.cache_resident(&mnist_4()));
        assert!(I7_4790.cache_resident(&har_4()));
        assert!(!I7_4790.cache_resident(&mnist_8()));
        assert!(!I7_4790.cache_resident(&har_6()));
        assert!(!ARM_CORTEX_A9.cache_resident(&mnist_4()));
    }

    #[test]
    fn cache_cliff_slows_deep_networks_superlinearly() {
        // mnist8 has ~3.0× the parameters of mnist4 but must be >3.5×
        // slower on the mobile CPU because it falls out of cache
        let t4 = I7_5600U.network_time(&mnist_4(), 1);
        let t8 = I7_5600U.network_time(&mnist_8(), 1);
        assert!(t8 / t4 > 3.5, "ratio {}", t8 / t4);
    }

    #[test]
    fn desktop_beats_mobile_beats_arm() {
        for spec in [mnist_4(), har_6()] {
            let arm = ARM_CORTEX_A9.network_time(&spec, 1);
            let mobile = I7_5600U.network_time(&spec, 1);
            let desktop = I7_4790.network_time(&spec, 1);
            assert!(arm > mobile && mobile > desktop, "{}", spec.name);
        }
    }

    #[test]
    fn hyperthreads_degrade_like_table2() {
        // i7-4790: 4 threads fastest, 8 threads slower again
        let t1 = I7_4790.network_time(&mnist_4(), 1);
        let t4 = I7_4790.network_time(&mnist_4(), 4);
        let t8 = I7_4790.network_time(&mnist_4(), 8);
        assert!(t4 < t1);
        assert!(t8 > t4);
        // i7-5600U: 2 fastest, 4 (SMT) slower
        let m1 = I7_5600U.network_time(&mnist_4(), 1);
        let m2 = I7_5600U.network_time(&mnist_4(), 2);
        let m4 = I7_5600U.network_time(&mnist_4(), 4);
        assert!(m2 < m1 && m4 > m2);
    }

    #[test]
    fn memory_bound_networks_do_not_scale_with_threads() {
        let t1 = I7_5600U.network_time(&har_6(), 1);
        let t2 = I7_5600U.network_time(&har_6(), 2);
        // memory bound: threads change nothing on the max() side
        assert!((t2 / t1 - 1.0).abs() < 0.05, "{t1} vs {t2}");
    }

    #[test]
    fn thread_sweep_matches_table2_rows() {
        assert_eq!(table2_thread_sweep("Intel i7-4790"), vec![1, 4, 8]);
        assert_eq!(table2_thread_sweep("ARM Cortex-A9"), vec![1]);
    }
}
