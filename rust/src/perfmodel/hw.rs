//! §4.4 closed-form throughput model of the FPGA accelerators.
//!
//! Cycle count of layer j→j+1 over N samples (general form):
//! ```text
//! ceil(s_{j+1}/m) · ceil(s_j·(1−q_prune)/r) · N            (compute)
//! t_mem = s_{j+1}·s_j·b_w·q_ovh·(1−q_prune)·N / (T_mem·n)  (weights)
//! t_proc = max(t_calc, t_mem)
//! n_opt ≈ m·r·f_pu·b_w·q_ovh / T_mem
//! ```

use crate::nn::spec::NetworkSpec;

/// Hardware configuration of one accelerator build.
#[derive(Debug, Clone, Copy)]
pub struct HwConfig {
    /// Neurons processed in parallel (processing units).
    pub m: usize,
    /// Parallel MAC lanes per processing unit.
    pub r: usize,
    /// Processing-unit clock (Hz) — the paper uses 100 MHz.
    pub f_pu: f64,
    /// Effective memory throughput for weight streaming (bytes/s).
    pub t_mem_bytes: f64,
    /// Stored bits per weight (16 for Q7.8).
    pub b_weight_bits: u32,
    /// Stream overhead factor (1.0 dense, 4/3 for the pruned tuple format).
    pub q_overhead: f64,
    /// Batch size n (weight reuse factor).
    pub batch: usize,
}

impl HwConfig {
    /// The paper's batch-processing design at a given batch size and MAC
    /// budget (Table 2 lists the m achievable per batch size).
    pub fn batch_design(m: usize, batch: usize, t_mem_bytes: f64) -> Self {
        Self {
            m,
            r: 1,
            f_pu: 100e6,
            t_mem_bytes,
            b_weight_bits: 16,
            q_overhead: 1.0,
            batch,
        }
    }

    /// The paper's pruning design: m = 4 coprocessors × r = 3 lanes.
    pub fn pruning_design(t_mem_bytes: f64) -> Self {
        Self {
            m: 4,
            r: 3,
            f_pu: 100e6,
            t_mem_bytes,
            b_weight_bits: 16,
            q_overhead: crate::sparse::Q_OVERHEAD,
            batch: 1,
        }
    }

    /// §7's envisaged combined design (m = 6, r = 3, n = 3).
    pub fn combined_design(t_mem_bytes: f64) -> Self {
        Self {
            m: 6,
            r: 3,
            f_pu: 100e6,
            t_mem_bytes,
            b_weight_bits: 16,
            q_overhead: crate::sparse::Q_OVERHEAD,
            batch: 3,
        }
    }

    pub fn total_macs(&self) -> usize {
        self.m * self.r
    }
}

/// Timing decomposition for one layer transition.
#[derive(Debug, Clone, Copy)]
pub struct LayerTiming {
    /// Compute-side seconds for N samples.
    pub t_calc: f64,
    /// Memory-side seconds for N samples.
    pub t_mem: f64,
}

impl LayerTiming {
    /// Compute and transfer overlap; the max dominates (§4.4).
    pub fn t_proc(&self) -> f64 {
        self.t_calc.max(self.t_mem)
    }

    pub fn memory_bound(&self) -> bool {
        self.t_mem > self.t_calc
    }
}

/// §4.4 cycle count for layer j→j+1 (exact integer form, batch design adds
/// the m·c_a activation drain which is negligible and included by the
/// simulator instead).
pub fn layer_cycles(
    cfg: &HwConfig,
    s_out: usize,
    s_in: usize,
    q_prune: f64,
    n_samples: usize,
) -> u64 {
    let sections = s_out.div_ceil(cfg.m) as u64;
    let remaining = ((s_in as f64) * (1.0 - q_prune)).ceil() as usize;
    let words = remaining.div_ceil(cfg.r) as u64;
    sections * words * n_samples as u64
}

/// §4.4 timing for one layer transition over `n_samples` (N in the paper).
pub fn layer_timing(
    cfg: &HwConfig,
    s_out: usize,
    s_in: usize,
    q_prune: f64,
    n_samples: usize,
) -> LayerTiming {
    let cycles = layer_cycles(cfg, s_out, s_in, q_prune, n_samples);
    let t_calc = cycles as f64 / cfg.f_pu;
    let weight_bytes = (s_out as f64)
        * (s_in as f64)
        * (f64::from(cfg.b_weight_bits) / 8.0)
        * cfg.q_overhead
        * (1.0 - q_prune);
    // weights are re-streamed once per batch of n samples
    let t_mem = weight_bytes * (n_samples as f64 / cfg.batch as f64) / cfg.t_mem_bytes;
    LayerTiming { t_calc, t_mem }
}

/// Whole-network processing time for N samples; per-layer q_prune may be
/// empty (dense) or one factor per weight matrix.
pub fn network_time(cfg: &HwConfig, spec: &NetworkSpec, q_prune: &[f64], n_samples: usize) -> f64 {
    let shapes = spec.weight_shapes();
    assert!(q_prune.is_empty() || q_prune.len() == shapes.len());
    shapes
        .iter()
        .enumerate()
        .map(|(l, &(o, i))| {
            let q = q_prune.get(l).copied().unwrap_or(0.0);
            layer_timing(cfg, o, i, q, n_samples).t_proc()
        })
        .sum()
}

/// Per-sample seconds at steady state (N → one full batch).
pub fn per_sample_time(cfg: &HwConfig, spec: &NetworkSpec, q_prune: &[f64]) -> f64 {
    network_time(cfg, spec, q_prune, cfg.batch) / cfg.batch as f64
}

/// §4.4 optimal batch size: t_calc = t_mem.
pub fn n_opt(cfg: &HwConfig) -> f64 {
    (cfg.m * cfg.r) as f64 * cfg.f_pu * (f64::from(cfg.b_weight_bits) / 8.0) * cfg.q_overhead
        / cfg.t_mem_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::spec::{har_6, mnist_4};

    /// The calibrated ZedBoard effective weight-stream throughput used
    /// throughout the benches (see sim::memory for the derivation).
    const T_MEM: f64 = 1.44e9;

    #[test]
    fn paper_n_opt_about_12_66() {
        // §6.1: n_opt = 12.66 for m = 114, f_pu = 100 MHz, Q7.8.
        // Inverting the paper's figure gives T_mem = 114·1e8·2/12.66 ≈ 1.80 GB/s.
        let cfg = HwConfig::batch_design(114, 1, 114.0 * 100e6 * 2.0 / 12.66);
        assert!((n_opt(&cfg) - 12.66).abs() < 0.01);
    }

    #[test]
    fn t_proc_is_max_and_continuous() {
        let cfg = HwConfig::batch_design(114, 8, T_MEM);
        let t = layer_timing(&cfg, 800, 784, 0.0, 8);
        assert!(t.t_proc() >= t.t_calc && t.t_proc() >= t.t_mem);
        assert_eq!(t.t_proc(), t.t_calc.max(t.t_mem));
    }

    #[test]
    fn batch_reduces_memory_time_not_compute() {
        let c1 = HwConfig::batch_design(114, 1, T_MEM);
        let c8 = HwConfig::batch_design(114, 8, T_MEM);
        let t1 = layer_timing(&c1, 800, 784, 0.0, 8);
        let t8 = layer_timing(&c8, 800, 784, 0.0, 8);
        assert!((t1.t_calc - t8.t_calc).abs() < 1e-12);
        assert!((t1.t_mem / t8.t_mem - 8.0).abs() < 1e-9);
    }

    #[test]
    fn pruning_reduces_both_sides() {
        let cfg = HwConfig::pruning_design(T_MEM);
        let dense = layer_timing(&cfg, 2000, 561, 0.0, 1);
        let pruned = layer_timing(&cfg, 2000, 561, 0.9, 1);
        assert!(pruned.t_calc < dense.t_calc * 0.2);
        assert!(pruned.t_mem < dense.t_mem * 0.2);
    }

    #[test]
    fn small_batch_is_memory_bound_large_batch_compute_bound() {
        // the n_opt crossover property that defines the paper's trade-off
        let cfg1 = HwConfig::batch_design(114, 1, T_MEM);
        let cfg32 = HwConfig::batch_design(114, 32, T_MEM);
        assert!(layer_timing(&cfg1, 800, 784, 0.0, 1).memory_bound());
        assert!(!layer_timing(&cfg32, 800, 784, 0.0, 32).memory_bound());
        let opt = n_opt(&cfg1);
        assert!(opt > 1.0 && opt < 32.0, "n_opt {opt} outside sweep");
    }

    #[test]
    fn layer_cycles_matches_paper_formula() {
        let cfg = HwConfig::batch_design(114, 1, T_MEM);
        // ceil(800/114)·ceil(784/1)·1 = 8·784
        assert_eq!(layer_cycles(&cfg, 800, 784, 0.0, 1), 8 * 784);
        let p = HwConfig::pruning_design(T_MEM);
        // ceil(800/4)·ceil(784·0.25/3) = 200·ceil(196/3) = 200·66
        assert_eq!(layer_cycles(&p, 800, 784, 0.75, 1), 200 * 66);
    }

    #[test]
    fn network_time_sums_layers() {
        let cfg = HwConfig::batch_design(114, 16, T_MEM);
        let spec = mnist_4();
        let total = network_time(&cfg, &spec, &[], 16);
        let by_hand: f64 = spec
            .weight_shapes()
            .iter()
            .map(|&(o, i)| layer_timing(&cfg, o, i, 0.0, 16).t_proc())
            .sum();
        assert!((total - by_hand).abs() < 1e-15);
    }

    #[test]
    fn har6_pruned_faster_than_batch16() {
        // Table 2's headline: HAR-6 at q=0.94 (12 MACs) beats batch-16 (90)
        let batch16 = HwConfig::batch_design(90, 16, T_MEM);
        let pruning = HwConfig::pruning_design(T_MEM);
        let spec = har_6();
        let t_batch = per_sample_time(&batch16, &spec, &[]);
        let t_prune = per_sample_time(&pruning, &spec, &[0.94; 5]);
        assert!(
            t_prune < t_batch,
            "pruned {t_prune} should beat batch {t_batch}"
        );
    }
}
