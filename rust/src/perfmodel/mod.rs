//! Analytic performance models.
//!
//! * [`hw`] — the paper's §4.4 throughput formulas for the FPGA designs:
//!   `t_calc`, `t_mem`, `t_proc = max(...)`, and the optimal batch size
//!   `n_opt`.  These are the closed forms the cycle simulator is validated
//!   against (integration tests assert agreement within tolerance).
//! * [`machine`] — cache-aware roofline models of the paper's three
//!   software platforms (Table 1), regenerating the software rows of
//!   Table 2 without the original hardware.
//! * [`gops`] — operation counting and GOps/s reporting (§6.1).

pub mod gops;
pub mod hw;
pub mod machine;

pub use gops::{gops_per_sec, macs_to_ops};
pub use hw::{HwConfig, LayerTiming};
pub use machine::{MachineModel, ARM_CORTEX_A9, I7_4790, I7_5600U};
