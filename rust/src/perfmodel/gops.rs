//! Operation counting and GOps/s reporting (paper §6.1).
//!
//! The paper counts MAC operations as two ops (multiply + accumulate) and
//! reports: batch-16 → 4.48 / 5.00 GOps/s (MNIST-8 / HAR-6), pruning →
//! 0.8 GOps/s raw, "equivalent" to 2.91 / 3.58 GOps/s dense because the
//! removed operations still count toward the dense workload.

use crate::nn::spec::NetworkSpec;

/// MACs → ops (multiply + add).
pub fn macs_to_ops(macs: usize) -> f64 {
    2.0 * macs as f64
}

/// GOps/s given per-sample seconds (dense operation count).
pub fn gops_per_sec(spec: &NetworkSpec, seconds_per_sample: f64) -> f64 {
    macs_to_ops(spec.macs_per_sample()) / seconds_per_sample / 1e9
}

/// Raw GOps/s actually executed by a pruned design (only remaining MACs).
pub fn gops_per_sec_pruned(spec: &NetworkSpec, q_prune: f64, seconds_per_sample: f64) -> f64 {
    macs_to_ops(spec.macs_per_sample()) * (1.0 - q_prune) / seconds_per_sample / 1e9
}

/// "Dense-equivalent" GOps/s of a pruned run (the §6.1 comparison number:
/// what a dense design would need to sustain to match the latency).
pub fn gops_equivalent(spec: &NetworkSpec, seconds_per_sample: f64) -> f64 {
    gops_per_sec(spec, seconds_per_sample)
}

/// Throughput-per-resource ratios used in the related-work comparison.
#[derive(Debug, Clone)]
pub struct ResourceEfficiency {
    pub gops: f64,
    pub dsp_slices: usize,
    pub luts: usize,
    pub ffs: usize,
}

impl ResourceEfficiency {
    pub fn gops_per_dsp(&self) -> f64 {
        self.gops / self.dsp_slices.max(1) as f64
    }
    pub fn gops_per_klut(&self) -> f64 {
        self.gops / (self.luts.max(1) as f64 / 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::spec::{har_6, mnist_8};

    #[test]
    fn ops_counting() {
        assert_eq!(macs_to_ops(100), 200.0);
        // MNIST-8 at the paper's 0.768 ms/sample → ~10 GOps... the paper's
        // 4.48 GOps/s figure implies ~1.71 ms; they count per *batch
        // pipeline* sustained rate.  We only assert internal consistency:
        let spec = mnist_8();
        let g = gops_per_sec(&spec, 1.712e-3);
        assert!((g - 4.48).abs() < 0.05, "{g}");
    }

    #[test]
    fn har6_gops_matches_paper_figure() {
        // 5.00 GOps/s at the implied sustained rate
        let spec = har_6();
        let g = gops_per_sec(&spec, 2.19e-3);
        assert!((g - 5.0).abs() < 0.05, "{g}");
    }

    #[test]
    fn pruned_raw_vs_equivalent() {
        let spec = har_6();
        let t = 0.42e-3; // Table 2 pruning HAR-6
        let raw = gops_per_sec_pruned(&spec, 0.94, t);
        let equiv = gops_equivalent(&spec, t);
        assert!(raw < equiv);
        assert!((equiv / raw - 1.0 / (1.0 - 0.94)).abs() < 1e-9);
    }

    #[test]
    fn resource_efficiency_ratios() {
        let e = ResourceEfficiency {
            gops: 4.48,
            dsp_slices: 90,
            luts: 30_000,
            ffs: 40_000,
        };
        assert!((e.gops_per_dsp() - 4.48 / 90.0).abs() < 1e-12);
        assert!(e.gops_per_klut() > 0.0);
    }
}
