//! EIE-style stream encodings for the `.rpz` artifact.
//!
//! Three rungs, each attacking a different term of the CSR byte budget
//! (`u32` column index + `i16` value per non-zero, `u32` per row pointer):
//!
//! * **Delta-coded columns** — columns are strictly increasing within a
//!   row, so the stream stores *gaps* instead of absolute indices: one
//!   byte per entry for gaps ≤ 255, a `0x00` escape + `u32` for larger
//!   jumps (a valid gap is never 0, so the escape byte is free).  This is
//!   EIE's 4-bit relative index idea at byte granularity — 4× smaller
//!   column metadata with a trivial decoder.
//! * **Nibble-coded columns** — EIE's relative index at its native 4-bit
//!   granularity: two gaps per byte, with a two-level escape (nibble `0x0`
//!   → one byte, byte `0x00` → `u32`) for the rare large jump.  At prune
//!   0.9 most gaps fit a nibble, so this halves the dominant cost of the
//!   delta stream; [`encode_columns`] picks it only when it actually comes
//!   out smaller than both the byte-delta and Huffman forms.
//! * **Optional Huffman pass** — the gap bytes of a pruned layer are
//!   highly skewed (small gaps dominate), so the canonical byte-alphabet
//!   coder from [`crate::sparse::huffman`] often beats the plain bytes;
//!   a leading tag byte records which form was stored, chosen at encode
//!   time by whichever is smaller (deterministic, self-describing).
//! * **Codebook values** — deterministic k-means clusters the non-zero
//!   Q7.8 values into ≤ 16 levels (EIE's weight sharing); values become
//!   4-bit indices into a shared lookup table, packed two per byte on
//!   disk.  Lossy — the compression search only accepts it for a layer
//!   when the *measured* accuracy stays inside the budget.
//!
//! Everything here is pure byte/array transformation; the container
//! framing lives in [`super::artifact`], the kernels that execute the
//! decoded forms in [`crate::tensor`].

use anyhow::{bail, ensure, Result};

use crate::sparse::huffman::{self, Codebook, EncodedStream};
use crate::tensor::{CsrMatI, MatI};

/// How a `.rpz` layer's sparse payload is stored (CLI `--encoding`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactEncoding {
    /// Absolute `u32` column indices (the v1 format).
    Raw,
    /// Delta-coded columns with the auto-selected Huffman pass.
    Delta,
    /// Delta-coded columns + 4-bit codebook-quantized values.
    Codebook,
}

impl ArtifactEncoding {
    pub fn name(self) -> &'static str {
        match self {
            ArtifactEncoding::Raw => "raw",
            ArtifactEncoding::Delta => "delta",
            ArtifactEncoding::Codebook => "codebook",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "raw" => Ok(ArtifactEncoding::Raw),
            "delta" => Ok(ArtifactEncoding::Delta),
            "codebook" => Ok(ArtifactEncoding::Codebook),
            other => bail!("unknown encoding {other:?} (expected raw|delta|codebook)"),
        }
    }
}

/// Escape byte for gaps ≥ 256 (a real gap is always ≥ 1).
const GAP_ESCAPE: u8 = 0x00;
/// Payload tag: plain delta bytes follow.
const TAG_PLAIN: u8 = 0;
/// Payload tag: Huffman container follows.
const TAG_HUFFMAN: u8 = 1;
/// Payload tag: nibble-granularity gap stream follows.
const TAG_NIBBLE: u8 = 2;

/// Delta-encode the per-row column gaps of a CSR matrix (no Huffman).
pub fn delta_encode_cols(csr: &CsrMatI) -> Vec<u8> {
    let mut out = Vec::with_capacity(csr.nnz());
    for o in 0..csr.rows() {
        let (idx, _) = csr.row(o);
        let mut prev = -1i64;
        for &c in idx {
            let gap = i64::from(c) - prev;
            debug_assert!(gap >= 1, "columns not strictly increasing");
            if gap <= 255 {
                out.push(gap as u8);
            } else {
                out.push(GAP_ESCAPE);
                out.extend_from_slice(&(gap as u32).to_le_bytes());
            }
            prev = i64::from(c);
        }
    }
    out
}

/// Inverse of [`delta_encode_cols`]: rebuild absolute column indices from
/// the gap stream, row structure taken from `row_ptr`.
pub fn delta_decode_cols(bytes: &[u8], row_ptr: &[usize], cols: usize) -> Result<Vec<u32>> {
    let nnz = *row_ptr.last().unwrap_or(&0);
    let mut col_idx = Vec::with_capacity(nnz);
    let mut pos = 0usize;
    for o in 0..row_ptr.len().saturating_sub(1) {
        let row_nnz = row_ptr[o + 1] - row_ptr[o];
        let mut prev = -1i64;
        for _ in 0..row_nnz {
            ensure!(pos < bytes.len(), "row {o}: gap stream truncated");
            let b = bytes[pos];
            pos += 1;
            let gap = if b == GAP_ESCAPE {
                ensure!(pos + 4 <= bytes.len(), "row {o}: escaped gap truncated");
                let g = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
                pos += 4;
                ensure!(g >= 1, "row {o}: zero gap");
                i64::from(g)
            } else {
                i64::from(b)
            };
            let col = prev + gap;
            ensure!(col < cols as i64, "row {o}: column {col} out of range");
            col_idx.push(col as u32);
            prev = col;
        }
    }
    ensure!(pos == bytes.len(), "trailing bytes in gap stream");
    Ok(col_idx)
}

/// Nibble-encode the per-row column gaps of a CSR matrix: gaps 1–15 cost
/// one nibble; a `0x0` escape nibble is followed by one byte (two
/// nibbles, low first) covering gaps up to 255; a zero escape *byte*
/// widens once more to a `u32` (eight nibbles, LE).  Packed two nibbles
/// per byte, low nibble first; an odd count pads with a zero nibble the
/// decoder never reads (it stops at the row-pointer gap count).
pub fn nibble_encode_cols(csr: &CsrMatI) -> Vec<u8> {
    let mut nibs = Vec::with_capacity(csr.nnz());
    for o in 0..csr.rows() {
        let (idx, _) = csr.row(o);
        let mut prev = -1i64;
        for &c in idx {
            let gap = i64::from(c) - prev;
            debug_assert!(gap >= 1, "columns not strictly increasing");
            if gap <= 15 {
                nibs.push(gap as u8);
            } else if gap <= 255 {
                nibs.push(0);
                let b = gap as u8;
                nibs.push(b & 0x0F);
                nibs.push(b >> 4);
            } else {
                nibs.push(0);
                nibs.push(0);
                nibs.push(0);
                for byte in (gap as u32).to_le_bytes() {
                    nibs.push(byte & 0x0F);
                    nibs.push(byte >> 4);
                }
            }
            prev = i64::from(c);
        }
    }
    pack_nibbles(&nibs)
}

/// Pull the next nibble (low half first) off a packed stream.
fn read_nibble(bytes: &[u8], pos: &mut usize) -> Result<u8> {
    ensure!(*pos < bytes.len() * 2, "gap nibble stream truncated");
    let b = bytes[*pos / 2];
    let n = if *pos % 2 == 0 { b & 0x0F } else { b >> 4 };
    *pos += 1;
    Ok(n)
}

/// Inverse of [`nibble_encode_cols`]: rebuild absolute column indices
/// from the packed nibble stream, row structure taken from `row_ptr`.
pub fn nibble_decode_cols(bytes: &[u8], row_ptr: &[usize], cols: usize) -> Result<Vec<u32>> {
    let nnz = *row_ptr.last().unwrap_or(&0);
    let mut col_idx = Vec::with_capacity(nnz);
    let mut pos = 0usize;
    for o in 0..row_ptr.len().saturating_sub(1) {
        let row_nnz = row_ptr[o + 1] - row_ptr[o];
        let mut prev = -1i64;
        for _ in 0..row_nnz {
            let n = read_nibble(bytes, &mut pos)?;
            let gap = if n != 0 {
                i64::from(n)
            } else {
                let lo = read_nibble(bytes, &mut pos)?;
                let hi = read_nibble(bytes, &mut pos)?;
                let b = lo | (hi << 4);
                if b != 0 {
                    i64::from(b)
                } else {
                    let mut raw = [0u8; 4];
                    for byte in raw.iter_mut() {
                        let lo = read_nibble(bytes, &mut pos)?;
                        let hi = read_nibble(bytes, &mut pos)?;
                        *byte = lo | (hi << 4);
                    }
                    let g = u32::from_le_bytes(raw);
                    ensure!(g >= 1, "row {o}: zero gap");
                    i64::from(g)
                }
            };
            let col = prev + gap;
            ensure!(col < cols as i64, "row {o}: column {col} out of range");
            col_idx.push(col as u32);
            prev = col;
        }
    }
    // all nibbles consumed, modulo the single pad nibble of an odd count
    ensure!(bytes.len() == pos.div_ceil(2), "trailing bytes in gap nibble stream");
    Ok(col_idx)
}

/// Encode a CSR matrix's column stream for storage: delta bytes, the
/// nibble form, and the Huffman pass race on size; the smallest wins,
/// ties broken toward the older formats so existing payloads are stable.
/// Self-describing via the leading tag byte; decode with
/// [`decode_columns`].
pub fn encode_columns(csr: &CsrMatI) -> Vec<u8> {
    let delta = delta_encode_cols(csr);
    let nibble = nibble_encode_cols(csr);
    let es = huffman::encode_bytes(&delta);
    // tag + raw_len + bit_len + 256-byte length table + bits
    let huff_size = 1 + 4 + 8 + 256 + es.bits.len();
    let plain_size = 1 + delta.len();
    let nibble_size = 1 + nibble.len();
    if nibble_size < plain_size && nibble_size < huff_size {
        let mut out = Vec::with_capacity(nibble_size);
        out.push(TAG_NIBBLE);
        out.extend_from_slice(&nibble);
        out
    } else if huff_size < plain_size {
        let mut out = Vec::with_capacity(huff_size);
        out.push(TAG_HUFFMAN);
        out.extend_from_slice(&(es.raw_len as u32).to_le_bytes());
        out.extend_from_slice(&(es.bit_len as u64).to_le_bytes());
        out.extend_from_slice(&es.codebook.lengths);
        out.extend_from_slice(&es.bits);
        out
    } else {
        let mut out = Vec::with_capacity(plain_size);
        out.push(TAG_PLAIN);
        out.extend_from_slice(&delta);
        out
    }
}

/// Decode a [`encode_columns`] payload back to absolute column indices.
pub fn decode_columns(payload: &[u8], row_ptr: &[usize], cols: usize) -> Result<Vec<u32>> {
    ensure!(!payload.is_empty(), "empty column payload");
    match payload[0] {
        TAG_PLAIN => delta_decode_cols(&payload[1..], row_ptr, cols),
        TAG_HUFFMAN => {
            let body = &payload[1..];
            ensure!(body.len() >= 4 + 8 + 256, "huffman container truncated");
            let raw_len = u32::from_le_bytes(body[..4].try_into().unwrap()) as usize;
            let bit_len = u64::from_le_bytes(body[4..12].try_into().unwrap());
            ensure!(bit_len <= usize::MAX as u64, "bit length overflows");
            let mut lengths = [0u8; 256];
            lengths.copy_from_slice(&body[12..268]);
            let es = EncodedStream {
                codebook: Codebook::from_lengths(lengths),
                bits: body[268..].to_vec(),
                bit_len: bit_len as usize,
                raw_len,
            };
            let delta = huffman::decode(&es)?;
            delta_decode_cols(&delta, row_ptr, cols)
        }
        TAG_NIBBLE => nibble_decode_cols(&payload[1..], row_ptr, cols),
        other => bail!("unknown column payload tag {other}"),
    }
}

/// Pack 4-bit codes two per byte (low nibble first).
pub fn pack_nibbles(codes: &[u8]) -> Vec<u8> {
    debug_assert!(codes.iter().all(|&c| c < 16));
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let hi = pair.get(1).copied().unwrap_or(0);
        out.push(pair[0] | (hi << 4));
    }
    out
}

/// Unpack `n` 4-bit codes from a [`pack_nibbles`] stream.
pub fn unpack_nibbles(bytes: &[u8], n: usize) -> Result<Vec<u8>> {
    ensure!(bytes.len() == n.div_ceil(2), "{} bytes for {n} nibbles", bytes.len());
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let b = bytes[i / 2];
        out.push(if i % 2 == 0 { b & 0x0F } else { b >> 4 });
    }
    Ok(out)
}

/// Codebook capacity — 4-bit codes, EIE's fully-connected-layer setting.
pub const CODEBOOK_SIZE: usize = 16;
/// Lloyd refinement passes (fixed count keeps the quantizer deterministic
/// and fast; convergence beyond a handful of passes is noise at 16 bins).
const KMEANS_ITERS: usize = 10;

/// Deterministic k-means over the non-zero values: ≤ 16 sorted distinct
/// levels.  Percentile initialisation over the sorted multiset, fixed
/// Lloyd iteration count, ties broken toward the lower centroid — same
/// inputs, same codebook, every time.
pub fn codebook_levels(vals: &[i32]) -> Vec<i32> {
    let mut sorted: Vec<i32> = vals.iter().copied().filter(|&v| v != 0).collect();
    sorted.sort_unstable();
    let mut distinct = sorted.clone();
    distinct.dedup();
    if distinct.len() <= CODEBOOK_SIZE {
        return distinct;
    }
    // init at the (i + 0.5)/16 percentiles of the value distribution
    let n = sorted.len();
    let mut centroids: Vec<f64> = (0..CODEBOOK_SIZE)
        .map(|i| f64::from(sorted[(2 * i + 1) * n / (2 * CODEBOOK_SIZE)]))
        .collect();
    for _ in 0..KMEANS_ITERS {
        let mut sums = [0i64; CODEBOOK_SIZE];
        let mut counts = [0u64; CODEBOOK_SIZE];
        for &v in &sorted {
            let c = nearest_centroid(&centroids, f64::from(v));
            sums[c] += i64::from(v);
            counts[c] += 1;
        }
        for c in 0..CODEBOOK_SIZE {
            if counts[c] > 0 {
                centroids[c] = sums[c] as f64 / counts[c] as f64;
            }
            // empty cluster keeps its centroid — deterministic, and the
            // final dedup collapses any that never attract a value
        }
    }
    let mut levels: Vec<i32> = centroids
        .iter()
        .map(|&c| (c.round() as i64).clamp(i64::from(i16::MIN), i64::from(i16::MAX)) as i32)
        .filter(|&v| v != 0) // zero means "pruned", never a codebook entry
        .collect();
    levels.sort_unstable();
    levels.dedup();
    levels
}

fn nearest_centroid(centroids: &[f64], v: f64) -> usize {
    let mut best = 0usize;
    let mut best_d = f64::INFINITY;
    for (i, &c) in centroids.iter().enumerate() {
        let d = (v - c).abs();
        // strict < keeps the lowest index on ties
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

/// Snap every non-zero of `m` to its nearest codebook level (zeros stay
/// zero — pruning is encoded by absence, not by a level).  The result has
/// ≤ 16 distinct non-zero values, i.e. it is exactly representable as a
/// [`crate::tensor::CsrCodebookMatI`].
pub fn codebook_quantize_matrix(m: &MatI) -> MatI {
    let levels = codebook_levels(&m.data);
    let mut out = m.clone();
    if levels.is_empty() {
        return out;
    }
    for v in out.data.iter_mut() {
        if *v != 0 {
            *v = nearest_level(&levels, *v);
        }
    }
    out
}

fn nearest_level(levels: &[i32], v: i32) -> i32 {
    // levels are sorted: binary-search the insertion point, compare the
    // two neighbours, ties toward the lower level
    match levels.binary_search(&v) {
        Ok(i) => levels[i],
        Err(i) => {
            let lo = i.checked_sub(1).map(|j| levels[j]);
            let hi = levels.get(i).copied();
            match (lo, hi) {
                (Some(a), Some(b)) => {
                    if i64::from(v) - i64::from(a) <= i64::from(b) - i64::from(v) {
                        a
                    } else {
                        b
                    }
                }
                (Some(a), None) => a,
                (None, Some(b)) => b,
                (None, None) => unreachable!("levels non-empty"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Xoshiro256;

    fn rand_sparse(rows: usize, cols: usize, density: f64, rng: &mut Xoshiro256) -> MatI {
        let mut m = MatI::zeros(rows, cols);
        for v in m.data.iter_mut() {
            if rng.bernoulli(density) {
                *v = (rng.normal_scaled(0.0, 120.0) as i32).clamp(-32768, 32767);
            }
        }
        m
    }

    #[test]
    fn delta_roundtrip_with_large_gaps() {
        // a 1000-column row with nnz at 0, 990 forces the u32 escape path
        let mut m = MatI::zeros(2, 1000);
        m.row_mut(0)[0] = 5;
        m.row_mut(0)[990] = -7;
        m.row_mut(1)[999] = 3; // first gap 1000 also escapes
        let csr = CsrMatI::from_dense(&m);
        let delta = delta_encode_cols(&csr);
        assert!(delta.contains(&GAP_ESCAPE));
        let back = delta_decode_cols(&delta, csr.row_ptr(), csr.cols()).unwrap();
        assert_eq!(back, csr.col_idx());
    }

    #[test]
    fn prop_encode_columns_roundtrips_and_beats_raw_when_pruned() {
        prop_check(40, |g| {
            let rows = g.usize(1..40);
            let cols = g.usize(1..400);
            let density = g.f64(0.0, 0.5);
            let mut rng = Xoshiro256::seed_from_u64(g.u64(0..=u64::MAX / 2));
            let csr = CsrMatI::from_dense(&rand_sparse(rows, cols, density, &mut rng));
            let payload = encode_columns(&csr);
            let back = decode_columns(&payload, csr.row_ptr(), csr.cols()).unwrap();
            if back != csr.col_idx() {
                return false;
            }
            // the nibble form must round-trip whether or not the size race
            // selected it for this matrix
            let nib = nibble_encode_cols(&csr);
            nibble_decode_cols(&nib, csr.row_ptr(), csr.cols()).unwrap() == csr.col_idx()
        });
    }

    #[test]
    fn nibble_gap_roundtrip_hits_every_escape_level() {
        // gaps 1 (nibble), 16 and 255 (byte escape), 990 and 10_000 (u32)
        let mut m = MatI::zeros(2, 12000);
        m.row_mut(0)[0] = 5; // gap 1
        m.row_mut(0)[15] = 2; // gap 15 (largest single nibble)
        m.row_mut(0)[31] = -4; // gap 16 (smallest byte escape)
        m.row_mut(0)[286] = 9; // gap 255 (largest byte escape)
        m.row_mut(0)[1276] = -1; // gap 990 (u32 escape)
        m.row_mut(1)[9999] = 3; // first gap 10_000 (u32 escape)
        let csr = CsrMatI::from_dense(&m);
        let packed = nibble_encode_cols(&csr);
        let back = nibble_decode_cols(&packed, csr.row_ptr(), csr.cols()).unwrap();
        assert_eq!(back, csr.col_idx());
        // truncation must error, not mis-decode
        assert!(nibble_decode_cols(&packed[..packed.len() - 1], csr.row_ptr(), csr.cols())
            .is_err());
        // trailing garbage beyond the pad nibble must be rejected too
        let mut long = packed.clone();
        long.push(0);
        assert!(nibble_decode_cols(&long, csr.row_ptr(), csr.cols()).is_err());
    }

    #[test]
    fn nibble_beats_delta_at_high_prune() {
        // prune 0.9 → mean gap ~10: most gaps fit one nibble, so the
        // nibble stream must undercut one-byte-per-gap delta coding
        let mut rng = Xoshiro256::seed_from_u64(9);
        let csr = CsrMatI::from_dense(&rand_sparse(300, 561, 0.1, &mut rng));
        let delta = delta_encode_cols(&csr);
        let nib = nibble_encode_cols(&csr);
        assert!(nib.len() < delta.len(), "{} nibble vs {} delta", nib.len(), delta.len());
        let payload = encode_columns(&csr);
        assert!(payload.len() <= 1 + nib.len(), "size race must not pick a larger form");
        let back = decode_columns(&payload, csr.row_ptr(), csr.cols()).unwrap();
        assert_eq!(back, csr.col_idx());
    }

    #[test]
    fn encoded_columns_smaller_than_raw_at_high_prune() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let csr = CsrMatI::from_dense(&rand_sparse(300, 561, 0.1, &mut rng));
        let payload = encode_columns(&csr);
        assert!(
            payload.len() < csr.nnz() * 4,
            "{} encoded vs {} raw",
            payload.len(),
            csr.nnz() * 4
        );
    }

    #[test]
    fn corrupt_column_payloads_rejected() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let csr = CsrMatI::from_dense(&rand_sparse(10, 50, 0.3, &mut rng));
        let payload = encode_columns(&csr);
        assert!(decode_columns(&[], csr.row_ptr(), csr.cols()).is_err());
        assert!(decode_columns(&[9], csr.row_ptr(), csr.cols()).is_err());
        // truncation must error, not mis-decode
        let cut = &payload[..payload.len() - 1];
        assert!(decode_columns(cut, csr.row_ptr(), csr.cols()).is_err());
    }

    #[test]
    fn nibble_roundtrip_odd_and_even() {
        for n in [0usize, 1, 2, 7, 8] {
            let codes: Vec<u8> = (0..n).map(|i| (i % 16) as u8).collect();
            let packed = pack_nibbles(&codes);
            assert_eq!(packed.len(), n.div_ceil(2));
            assert_eq!(unpack_nibbles(&packed, n).unwrap(), codes);
        }
        assert!(unpack_nibbles(&[0, 0], 1).is_err());
    }

    #[test]
    fn codebook_levels_cap_and_pass_through() {
        // ≤ 16 distinct values: identity
        let small: Vec<i32> = vec![-3, 5, 5, 9, 0, 0, -3];
        assert_eq!(codebook_levels(&small), vec![-3, 5, 9]);
        // wide distribution: clustered to ≤ 16 non-zero levels
        let mut rng = Xoshiro256::seed_from_u64(7);
        let wide: Vec<i32> = (0..5000)
            .map(|_| (rng.normal_scaled(0.0, 300.0) as i32).clamp(-32768, 32767))
            .collect();
        let levels = codebook_levels(&wide);
        assert!(!levels.is_empty() && levels.len() <= CODEBOOK_SIZE, "{}", levels.len());
        assert!(levels.windows(2).all(|w| w[0] < w[1]));
        assert!(!levels.contains(&0));
    }

    #[test]
    fn prop_quantized_matrix_is_codebook_representable() {
        prop_check(30, |g| {
            let rows = g.usize(1..25);
            let cols = g.usize(1..40);
            let mut rng = Xoshiro256::seed_from_u64(g.u64(0..=u64::MAX / 2));
            let m = rand_sparse(rows, cols, g.f64(0.0, 0.8), &mut rng);
            let q = codebook_quantize_matrix(&m);
            // zeros stay zero (prune structure preserved)
            if m.data.iter().zip(q.data.iter()).any(|(&a, &b)| (a == 0) != (b == 0)) {
                return false;
            }
            let mut distinct: Vec<i32> = q.data.iter().copied().filter(|&v| v != 0).collect();
            distinct.sort_unstable();
            distinct.dedup();
            distinct.len() <= CODEBOOK_SIZE
        });
    }

    #[test]
    fn quantizer_is_deterministic() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let m = rand_sparse(30, 40, 0.5, &mut rng);
        assert_eq!(codebook_quantize_matrix(&m).data, codebook_quantize_matrix(&m).data);
    }
}
