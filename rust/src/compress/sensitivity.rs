//! Per-layer pruning sensitivity sweep: prune one layer at a time at a
//! ladder of factors and measure the end-to-end accuracy delta on a
//! held-out slice.  The sweep is what turns "prune everything to 0.9"
//! into per-layer decisions: wide early layers usually shrug off 90 %
//! pruning while narrow output layers collapse, and the budgeted search
//! ([`crate::compress::search`]) spends the accuracy budget accordingly.

use anyhow::{ensure, Result};

use super::encoding::codebook_quantize_matrix;
use super::prune::prune_layer;
use super::{accuracy_q, EvalSet};
use crate::bench::report::Table;
use crate::nn::forward::QNetwork;

/// Default prune-factor ladder: brackets the paper's evaluated range
/// (Table 4 prunes the four networks to 0.72–0.94) plus a gentle 0.5
/// rung so insensitive layers are distinguishable from untouchable ones.
pub const DEFAULT_LADDER: [f64; 6] = [0.5, 0.6, 0.7, 0.8, 0.9, 0.95];

/// One (layer, factor) probe result.
#[derive(Debug, Clone)]
pub struct SensitivityPoint {
    pub layer: usize,
    pub factor: f64,
    /// Accuracy with only `layer` pruned at `factor`.
    pub accuracy: f64,
    /// Baseline accuracy minus `accuracy` (positive = hurts).
    pub delta: f64,
}

/// The full sweep: baseline + one point per (layer, ladder rung).
#[derive(Debug, Clone)]
pub struct SensitivityReport {
    pub network: String,
    pub baseline: f64,
    pub ladder: Vec<f64>,
    pub points: Vec<SensitivityPoint>,
    layers: usize,
}

/// Run the sweep: `layers × ladder` pruned-forward evaluations.
pub fn sweep(net: &QNetwork, eval: &EvalSet, ladder: &[f64]) -> Result<SensitivityReport> {
    ensure!(!ladder.is_empty(), "sensitivity ladder must not be empty");
    ensure!(!eval.is_empty(), "sensitivity eval slice must not be empty");
    let baseline = accuracy_q(net, eval)?;
    let mut points = Vec::with_capacity(net.weights.len() * ladder.len());
    for layer in 0..net.weights.len() {
        for &factor in ladder {
            let accuracy = accuracy_q(&prune_layer(net, layer, factor), eval)?;
            points.push(SensitivityPoint {
                layer,
                factor,
                accuracy,
                delta: baseline - accuracy,
            });
        }
    }
    Ok(SensitivityReport {
        network: net.spec.name.clone(),
        baseline,
        ladder: ladder.to_vec(),
        points,
        layers: net.weights.len(),
    })
}

/// Codebook-quantization sensitivity: accuracy delta of weight-sharing
/// each layer *alone* (16-level deterministic k-means), baseline minus
/// quantized (positive = hurts).  The search's codebook rung visits
/// layers in ascending order of this — the same least-sensitive-first
/// greedy the prune pass uses.
pub fn codebook_deltas(net: &QNetwork, eval: &EvalSet) -> Result<Vec<f64>> {
    ensure!(!eval.is_empty(), "sensitivity eval slice must not be empty");
    let baseline = accuracy_q(net, eval)?;
    (0..net.weights.len())
        .map(|layer| {
            let mut probe = net.clone();
            probe.weights[layer] = codebook_quantize_matrix(&probe.weights[layer]);
            Ok(baseline - accuracy_q(&probe, eval)?)
        })
        .collect()
}

impl SensitivityReport {
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Mean accuracy delta across the ladder for one layer — the search's
    /// ordering key (smaller = the layer tolerates pruning better).
    pub fn mean_delta(&self, layer: usize) -> f64 {
        let deltas: Vec<f64> = self
            .points
            .iter()
            .filter(|p| p.layer == layer)
            .map(|p| p.delta)
            .collect();
        deltas.iter().sum::<f64>() / deltas.len().max(1) as f64
    }

    /// Layer indices ordered least-sensitive first (ties break to the
    /// earlier layer, deterministically).
    pub fn layers_by_sensitivity(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.layers).collect();
        order.sort_by(|&a, &b| {
            self.mean_delta(a)
                .partial_cmp(&self.mean_delta(b))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        order
    }

    /// Render the sweep as a table (one row per layer, one column per
    /// rung) for the `compress` CLI and `bench compress`.
    pub fn render(&self) -> String {
        let mut header: Vec<String> = vec!["layer".into()];
        header.extend(self.ladder.iter().map(|q| format!("Δacc @ q={q:.2}")));
        let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(
            &format!(
                "per-layer pruning sensitivity ({}, baseline {:.3})",
                self.network, self.baseline
            ),
            &header_refs,
        );
        for layer in 0..self.layers {
            let mut cells = vec![layer.to_string()];
            for &q in &self.ladder {
                let p = self
                    .points
                    .iter()
                    .find(|p| p.layer == layer && (p.factor - q).abs() < 1e-12)
                    .expect("sweep covers every (layer, rung)");
                cells.push(format!("{:+.3}", -p.delta));
            }
            t.row(cells);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::random_qnet;
    use crate::data::har;
    use crate::nn::spec::NetworkSpec;
    use crate::compress::EvalSet;

    fn fixture() -> (QNetwork, EvalSet) {
        let spec = NetworkSpec::new("t", &[561, 16, 6]);
        (
            random_qnet(&spec, 7),
            EvalSet::from_dataset(&har::generate(50, 8)),
        )
    }

    #[test]
    fn sweep_covers_every_layer_and_rung() {
        let (net, eval) = fixture();
        let r = sweep(&net, &eval, &[0.5, 0.9]).unwrap();
        assert_eq!(r.layers(), 2);
        assert_eq!(r.points.len(), 4);
        assert_eq!(r.layers_by_sensitivity().len(), 2);
        for p in &r.points {
            assert!((0.0..=1.0).contains(&p.accuracy));
            assert!((r.baseline - p.accuracy - p.delta).abs() < 1e-12);
        }
        let table = r.render();
        assert!(table.contains("q=0.90"));
    }

    #[test]
    fn codebook_deltas_cover_layers_and_are_zero_when_lossless() {
        let (net, eval) = fixture();
        let deltas = codebook_deltas(&net, &eval).unwrap();
        assert_eq!(deltas.len(), 2);
        // a network already on ≤ 16 levels quantizes to itself: Δ = 0
        let mut tiny = net.clone();
        for w in tiny.weights.iter_mut() {
            for v in w.data.iter_mut() {
                *v = (*v).signum() * 100;
            }
        }
        let d = codebook_deltas(&tiny, &eval).unwrap();
        assert!(d.iter().all(|&x| x.abs() < 1e-12), "{d:?}");
    }

    #[test]
    fn empty_ladder_and_empty_eval_rejected() {
        let (net, eval) = fixture();
        assert!(sweep(&net, &eval, &[]).is_err());
        let empty = EvalSet {
            x: crate::tensor::MatI::zeros(0, 561),
            y: vec![],
        };
        assert!(sweep(&net, &empty, &[0.5]).is_err());
    }
}
