//! Offline model compression: accuracy-budgeted pruning that emits
//! servable compressed artifacts (paper §5.6 made operational).
//!
//! The paper's pruning result — compressed weight matrices cut data
//! transfers by an order of magnitude — only pays off in production if the
//! compression step and the execution engine are co-designed (the EIE
//! lesson).  This module is the offline half of that loop:
//!
//! 1. [`sensitivity`] — prune each layer alone at a ladder of factors and
//!    measure the accuracy delta on a held-out eval slice, so the search
//!    knows which layers tolerate aggressive pruning (the HAPM insight:
//!    per-layer thresholds beat one global factor).
//! 2. [`search`] — a greedy accuracy-budgeted search that assigns each
//!    layer the most aggressive ladder factor such that the *measured*
//!    end-to-end accuracy stays within `budget` of the dense baseline.
//!    Every move is accepted only after evaluation, so the invariant
//!    "never exceeds the budget on the search slice" holds by
//!    construction (and is property-tested).
//! 3. [`encoding`] — the EIE stream rungs: delta/Huffman-coded CSR
//!    columns (lossless) and the deterministic 16-level codebook
//!    quantizer (lossy; the search only accepts it inside the budget).
//! 4. [`artifact`] — the `.rpz` container: Q-format metadata, per-layer
//!    dense/CSR/delta/codebook blobs, and the calibrated
//!    `sparse_threshold` (from `bench calibrate`), so serving compiles
//!    kernels from the artifact's own calibration instead of a CLI flag
//!    ([`ExecPlan::compile_artifact`](crate::exec::ExecPlan::compile_artifact)).
//! 5. [`prune`] — the one magnitude-pruning implementation, shared with
//!    the simulator (`sim::pruning` re-exports it).
//!
//! The end-to-end path is `zynq-dnn compress` (CLI) →
//! `serve --artifact model.rpz` / `serve-pool --artifact model.rpz`;
//! `bench compress` reports the accuracy-vs-prune-vs-throughput curves
//! (EXPERIMENTS.md §compress, paper Fig. 7 / Table 4 side-by-side).

pub mod artifact;
pub mod encoding;
pub mod prune;
pub mod search;
pub mod sensitivity;

pub use artifact::{
    load_artifact, save_artifact, CompressedModel, IndexOverflowError, LayerBlob,
};
pub use encoding::{codebook_quantize_matrix, ArtifactEncoding, CODEBOOK_SIZE};
pub use prune::{prune_layer, prune_matrix, prune_per_layer, prune_qnetwork};
pub use search::{search, SearchConfig, SearchOutcome};
pub use sensitivity::{
    codebook_deltas, sweep, SensitivityPoint, SensitivityReport, DEFAULT_LADDER,
};

use anyhow::{ensure, Result};

use crate::data::Dataset;
use crate::nn::forward::{argmax_rows, QNetwork};
use crate::nn::quantize_matrix;
use crate::nn::spec::Activation;
use crate::tensor::{gemm_i32, MatI};

/// A labelled eval slice pre-quantized to the Q7.8 grid, so the sweep and
/// the search never pay the f32→Q7.8 conversion per probe.
#[derive(Debug, Clone)]
pub struct EvalSet {
    /// (samples × s_0) quantized inputs.
    pub x: MatI,
    pub y: Vec<usize>,
}

impl EvalSet {
    pub fn from_dataset(d: &Dataset) -> Self {
        Self {
            x: quantize_matrix(&d.x),
            y: d.y.clone(),
        }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
}

/// Classification accuracy of a quantized network on an eval slice.
///
/// Scored on identity-requantized output logits exactly like
/// [`train::evaluate_q`](crate::train::evaluate_q): sigmoid is monotone,
/// so argmax is unchanged in exact arithmetic, but the Q7.8 output grid
/// saturates confident logits to exactly 1.0 and would turn the
/// comparison into index-order tie-breaking — an encoding artifact, not a
/// datapath property the budget should charge for.
///
/// Runs the golden dense path (`gemm_i32` + `apply_acc`) directly over
/// the borrowed weights instead of compiling a plan: the sweep and the
/// search call this O(layers × ladder) times, and cloning every weight
/// matrix per probe just to flip one activation dominated their runtime.
pub fn accuracy_q(net: &QNetwork, eval: &EvalSet) -> Result<f64> {
    ensure!(
        eval.x.cols == net.spec.inputs(),
        "eval width {} != {}",
        eval.x.cols,
        net.spec.inputs()
    );
    ensure!(
        eval.x.rows == eval.y.len(),
        "eval has {} samples but {} labels",
        eval.x.rows,
        eval.y.len()
    );
    let last = net.weights.len() - 1;
    let mut a = eval.x.clone();
    for (j, (w, &act)) in net
        .weights
        .iter()
        .zip(net.spec.activations.iter())
        .enumerate()
    {
        let mut z = MatI::zeros(a.rows, w.rows);
        gemm_i32(&a, w, &mut z);
        let act = if j == last { Activation::Identity } else { act };
        for v in z.data.iter_mut() {
            *v = act.apply_acc(*v);
        }
        a = z;
    }
    let preds = argmax_rows(&a);
    let correct = preds
        .iter()
        .zip(eval.y.iter())
        .filter(|(p, y)| p == y)
        .count();
    Ok(correct as f64 / eval.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::random_qnet;
    use crate::data::har;
    use crate::nn::spec::NetworkSpec;

    #[test]
    fn accuracy_is_a_fraction_and_deterministic() {
        let spec = NetworkSpec::new("t", &[561, 24, 6]);
        let net = random_qnet(&spec, 1);
        let eval = EvalSet::from_dataset(&har::generate(60, 2));
        let a = accuracy_q(&net, &eval).unwrap();
        let b = accuracy_q(&net, &eval).unwrap();
        assert!((0.0..=1.0).contains(&a));
        assert_eq!(a, b);
    }

    #[test]
    fn accuracy_matches_evaluate_q_scoring() {
        // same identity-logit scoring rule as train::evaluate_q: a fully
        // zeroed network classifies everything as the tie-broken last
        // class, so both paths must agree on the degenerate case too
        let spec = NetworkSpec::new("t", &[561, 8, 6]);
        let mut net = random_qnet(&spec, 3);
        for w in net.weights.iter_mut() {
            w.data.fill(0);
        }
        let data = har::generate(40, 4);
        let eval = EvalSet::from_dataset(&data);
        let acc = accuracy_q(&net, &eval).unwrap();
        let want = data.y.iter().filter(|&&y| y == 5).count() as f64 / 40.0;
        assert!((acc - want).abs() < 1e-12, "{acc} vs {want}");
    }
}
