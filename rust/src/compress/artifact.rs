//! The `.rpz` servable compressed-model container.
//!
//! Layout (little endian):
//! ```text
//! magic   b"ZRPZ"                      4 bytes
//! u32     header_len
//! header  JSON (utf-8, header_len bytes)
//! blobs   per-layer payloads, header order
//! crc32   of everything after the magic (integrity check)
//! ```
//!
//! The header is JSON (parsed with the in-tree [`crate::config::json`]
//! parser — serde is not in the offline crate set) so the artifact is
//! self-describing without decoding the payload: network name,
//! architecture, Q-format, the calibrated `sparse_threshold` (from
//! `bench calibrate`), the accuracy budget/baselines the search measured,
//! and one entry per layer naming its encoding.  Payloads:
//!
//! * `dense` — `rows × cols` Q7.8 weights as `i16` (the format's range;
//!   [`crate::fixedpoint::quantize`] saturates to it, and the §5.6 stream
//!   encoder enforces it too).
//! * `csr`   — `row_ptr` as `u32[rows + 1]`, `col_idx` as `u32[nnz]`,
//!   `vals` as `i16[nnz]` — exactly the
//!   [`CsrMatI`](crate::tensor::CsrMatI) the `SparseQ` execution kernel
//!   consumes, so serving never densifies a compressed layer.
//! * `csr_delta` (v2) — `row_ptr` as `u32[rows + 1]`, then the tagged
//!   delta/Huffman column payload from
//!   [`encoding::encode_columns`](super::encoding::encode_columns)
//!   (`payload` bytes, named in the header), then `vals` as `i16[nnz]`.
//!   Decode-on-load into the same `CsrMatI` — the EIE relative-index
//!   rung, never densified.
//! * `codebook` (v2) — like `csr_delta` but values are EIE weight-shared:
//!   a 16-entry `i16` lookup table followed by 4-bit codes packed two per
//!   byte, decoded into a
//!   [`CsrCodebookMatI`](crate::tensor::CsrCodebookMatI) for the
//!   `CodebookQ` kernel.
//!
//! Which encoding a layer gets is decided *at save time* from the
//! artifact's own threshold: measured prune factor ≥ `sparse_threshold`
//! → sparse, stored in the [`ArtifactEncoding`] the producer picked
//! (`codebook` additionally requires the layer's values to already be
//! ≤ 16 levels — the search's codebook rung guarantees that for layers it
//! accepted; others fall back to `csr_delta`).
//! [`ExecPlan::compile_artifact`](crate::exec::ExecPlan::compile_artifact)
//! then maps sparse blobs to `SparseQ`/`CodebookQ` kernels and dense
//! blobs to `DenseQ` directly, which is what "the artifact embeds its
//! calibration" means operationally: no `--threshold` flag at serve time.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::encoding::{self, ArtifactEncoding};
use crate::config::json::{self, Json};
use crate::fixedpoint::{FRAC_BITS, Q78_MAX, Q78_MIN};
use crate::nn::forward::QNetwork;
use crate::nn::spec::{Activation, NetworkSpec};
use crate::nn::weights::{crc32, put_u32, Cursor};
use crate::tensor::{CsrCodebookMatI, CsrMatI, MatI};

const MAGIC: &[u8; 4] = b"ZRPZ";
/// v2 added the `csr_delta` and `codebook` layer encodings; v1 files
/// (dense/csr only) still load.
const VERSION: u32 = 2;

/// Typed save-time failure: an index field does not fit the `u32` the
/// on-disk format stores.  Converted into the [`anyhow`] chain via the
/// blanket `From` (it implements [`std::error::Error`]), so callers match
/// on the message while the save path keeps one early-return shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexOverflowError {
    pub layer: usize,
    pub field: &'static str,
    pub value: usize,
}

impl std::fmt::Display for IndexOverflowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "layer {}: {} value {} overflows the u32 artifact field",
            self.layer, self.field, self.value
        )
    }
}

impl std::error::Error for IndexOverflowError {}

/// Bounds-checked `usize → u32` for artifact fields — the silent-truncate
/// hazard the format invites (`as u32` would wrap).
fn u32_field(layer: usize, field: &'static str, value: usize) -> Result<u32> {
    if value > u32::MAX as usize {
        return Err(IndexOverflowError {
            layer,
            field,
            value,
        }
        .into());
    }
    Ok(value as u32)
}

/// One layer's stored weights.
#[derive(Debug, Clone)]
pub enum LayerBlob {
    /// Below the sparse threshold: plain dense Q7.8 storage.
    Dense(MatI),
    /// At/above the threshold: the CSR form the `SparseQ` kernel runs on,
    /// columns stored as absolute `u32`s (the v1 format).
    Csr(CsrMatI),
    /// CSR with delta/Huffman-coded columns on disk; decodes to the same
    /// `CsrMatI` (lossless — the EIE relative-index rung).
    CsrDelta(CsrMatI),
    /// Delta-coded columns + 4-bit weight-shared values for the
    /// `CodebookQ` kernel.
    Codebook(CsrCodebookMatI),
}

impl LayerBlob {
    pub fn shape(&self) -> (usize, usize) {
        match self {
            LayerBlob::Dense(m) => m.shape(),
            LayerBlob::Csr(m) | LayerBlob::CsrDelta(m) => m.shape(),
            LayerBlob::Codebook(m) => m.shape(),
        }
    }

    /// Measured prune factor (zero fraction) of this layer.
    pub fn prune_factor(&self) -> f64 {
        let (rows, cols) = self.shape();
        let total = (rows * cols).max(1);
        let nonzero = match self {
            LayerBlob::Dense(m) => m.data.iter().filter(|&&v| v != 0).count(),
            LayerBlob::Csr(m) | LayerBlob::CsrDelta(m) => m.nnz(),
            LayerBlob::Codebook(m) => m.nnz(),
        };
        1.0 - nonzero as f64 / total as f64
    }

    /// Payload bytes this blob serializes to (encoded forms pay the
    /// encode to measure it — reporting/save-path only, never serving).
    pub fn stored_bytes(&self) -> usize {
        match self {
            LayerBlob::Dense(m) => m.data.len() * 2,
            LayerBlob::Csr(m) => (m.rows() + 1) * 4 + m.nnz() * 4 + m.nnz() * 2,
            LayerBlob::CsrDelta(m) => {
                (m.rows() + 1) * 4 + encoding::encode_columns(m).len() + m.nnz() * 2
            }
            LayerBlob::Codebook(m) => {
                (m.rows() + 1) * 4
                    + encoding::encode_columns(&m.to_csr()).len()
                    + 32
                    + m.nnz().div_ceil(2)
            }
        }
    }

    /// What the same layer would cost in the raw v1 format (dense stays
    /// dense) — the baseline the `bench compress` encoded-payload column
    /// compares against.
    pub fn raw_stored_bytes(&self) -> usize {
        match self {
            LayerBlob::Dense(m) => m.data.len() * 2,
            LayerBlob::Csr(m) | LayerBlob::CsrDelta(m) => {
                (m.rows() + 1) * 4 + m.nnz() * 4 + m.nnz() * 2
            }
            LayerBlob::Codebook(m) => (m.rows() + 1) * 4 + m.nnz() * 4 + m.nnz() * 2,
        }
    }

    fn dense_weights(&self) -> MatI {
        match self {
            LayerBlob::Dense(m) => m.clone(),
            LayerBlob::Csr(m) | LayerBlob::CsrDelta(m) => m.to_dense(),
            LayerBlob::Codebook(m) => m.to_csr().to_dense(),
        }
    }
}

/// A compressed model: everything serving needs to reconstruct kernels
/// with the calibration baked in.
#[derive(Debug, Clone)]
pub struct CompressedModel {
    pub spec: NetworkSpec,
    /// Calibrated dense/CSR crossover the plan compiler applies.
    pub sparse_threshold: f64,
    /// Accuracy budget the search ran with.
    pub budget: f64,
    /// Dense-baseline accuracy on the search slice.
    pub baseline_accuracy: f64,
    /// Measured accuracy of the compressed network on the same slice.
    pub compressed_accuracy: f64,
    /// One blob per layer transition, spec order.
    pub layers: Vec<LayerBlob>,
}

impl CompressedModel {
    /// Package a (pruned) quantized network: each layer stores sparse
    /// when its measured prune factor reaches `sparse_threshold`, dense
    /// otherwise.  Sparse layers use the delta encoding (lossless, always
    /// no larger than raw on pruned layers).
    pub fn from_network(
        net: &QNetwork,
        sparse_threshold: f64,
        budget: f64,
        baseline_accuracy: f64,
        compressed_accuracy: f64,
    ) -> Result<Self> {
        Self::from_network_encoded(
            net,
            sparse_threshold,
            ArtifactEncoding::Delta,
            budget,
            baseline_accuracy,
            compressed_accuracy,
        )
    }

    /// [`Self::from_network`] with an explicit sparse-layer encoding (the
    /// CLI `--encoding` flag).  `Codebook` stores a layer weight-shared
    /// only when its values already fit 16 levels (what the search's
    /// codebook rung produces — storage itself must stay lossless), and
    /// falls back to `csr_delta` otherwise.
    pub fn from_network_encoded(
        net: &QNetwork,
        sparse_threshold: f64,
        encoding: ArtifactEncoding,
        budget: f64,
        baseline_accuracy: f64,
        compressed_accuracy: f64,
    ) -> Result<Self> {
        ensure!(
            sparse_threshold.is_finite() && sparse_threshold >= 0.0,
            "sparse_threshold must be finite and >= 0, got {sparse_threshold}"
        );
        for (j, w) in net.weights.iter().enumerate() {
            for &v in &w.data {
                ensure!(
                    (Q78_MIN..=Q78_MAX).contains(&v),
                    "layer {j}: weight {v} outside the Q7.8 (i16) range"
                );
            }
        }
        let prune = net.prune_factors();
        let layers = net
            .weights
            .iter()
            .zip(prune.iter())
            .map(|(w, &q)| {
                if q < sparse_threshold {
                    return LayerBlob::Dense(w.clone());
                }
                let csr = CsrMatI::from_dense(w);
                match encoding {
                    ArtifactEncoding::Raw => LayerBlob::Csr(csr),
                    ArtifactEncoding::Delta => LayerBlob::CsrDelta(csr),
                    ArtifactEncoding::Codebook => match CsrCodebookMatI::from_csr(&csr) {
                        Ok(cb) => LayerBlob::Codebook(cb),
                        Err(_) => LayerBlob::CsrDelta(csr),
                    },
                }
            })
            .collect();
        Ok(Self {
            spec: net.spec.clone(),
            sparse_threshold,
            budget,
            baseline_accuracy,
            compressed_accuracy,
            layers,
        })
    }

    /// Package a budgeted-search outcome (the usual producer) — sparse
    /// layers stored in the encoding the search ran with.
    pub fn from_outcome(
        outcome: &super::search::SearchOutcome,
        sparse_threshold: f64,
    ) -> Result<Self> {
        Self::from_network_encoded(
            &outcome.network,
            sparse_threshold,
            outcome.encoding,
            outcome.budget,
            outcome.baseline_accuracy,
            outcome.compressed_accuracy,
        )
    }

    /// Reconstruct the full quantized network (densifies CSR layers —
    /// tests and the f32-free eval path; serving compiles kernels from
    /// the blobs directly).
    pub fn to_qnetwork(&self) -> Result<QNetwork> {
        let weights = self.layers.iter().map(LayerBlob::dense_weights).collect();
        QNetwork::new(self.spec.clone(), weights)
    }

    /// Measured per-layer prune factors (recomputed from the blobs, never
    /// trusted from the header).
    pub fn prune_factors(&self) -> Vec<f64> {
        self.layers.iter().map(LayerBlob::prune_factor).collect()
    }

    /// Payload bytes across all layers.
    pub fn stored_bytes(&self) -> usize {
        self.layers.iter().map(LayerBlob::stored_bytes).sum()
    }

    /// What the same layers would cost in the raw v1 CSR format — the
    /// baseline for the encoded-payload column and the delta-beats-raw
    /// gate.
    pub fn raw_stored_bytes(&self) -> usize {
        self.layers.iter().map(LayerBlob::raw_stored_bytes).sum()
    }

    /// Dense 16-bit baseline the paper compares streams against.
    pub fn dense_bytes(&self) -> usize {
        self.spec.num_parameters() * 2
    }

    /// stored / dense payload ratio (< 1 once pruning wins over the CSR
    /// index overhead).
    pub fn compression_ratio(&self) -> f64 {
        self.stored_bytes() as f64 / self.dense_bytes().max(1) as f64
    }

    fn validate(&self) -> Result<()> {
        let shapes = self.spec.weight_shapes();
        ensure!(
            self.layers.len() == shapes.len(),
            "{}: {} layer blobs for {} weight matrices",
            self.spec.name,
            self.layers.len(),
            shapes.len()
        );
        for (j, (blob, &(o, i))) in self.layers.iter().zip(shapes.iter()).enumerate() {
            ensure!(
                blob.shape() == (o, i),
                "layer {j}: blob shape {:?} != spec {:?}",
                blob.shape(),
                (o, i)
            );
        }
        Ok(())
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn fnum(v: f64) -> Result<String> {
    ensure!(v.is_finite(), "non-finite number {v} cannot be stored");
    Ok(format!("{v}"))
}

fn render_header(model: &CompressedModel) -> Result<String> {
    let mut h = String::new();
    let _ = write!(
        h,
        "{{\"version\":{VERSION},\"network\":\"{}\",\"sizes\":[{}],\"activations\":[{}],",
        esc(&model.spec.name),
        model
            .spec
            .sizes
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>()
            .join(","),
        model
            .spec
            .activations
            .iter()
            .map(|a| format!("\"{}\"", a.name()))
            .collect::<Vec<_>>()
            .join(","),
    );
    let _ = write!(
        h,
        "\"qformat\":{{\"int_bits\":{},\"frac_bits\":{}}},",
        15 - FRAC_BITS,
        FRAC_BITS
    );
    let _ = write!(
        h,
        "\"sparse_threshold\":{},\"budget\":{},\"baseline_accuracy\":{},\
         \"compressed_accuracy\":{},",
        fnum(model.sparse_threshold)?,
        fnum(model.budget)?,
        fnum(model.baseline_accuracy)?,
        fnum(model.compressed_accuracy)?,
    );
    h.push_str("\"layers\":[");
    for (j, blob) in model.layers.iter().enumerate() {
        if j > 0 {
            h.push(',');
        }
        let (rows, cols) = blob.shape();
        match blob {
            LayerBlob::Dense(_) => {
                let _ = write!(
                    h,
                    "{{\"encoding\":\"dense\",\"rows\":{rows},\"cols\":{cols},\"prune\":{}}}",
                    fnum(blob.prune_factor())?
                );
            }
            LayerBlob::Csr(m) => {
                let _ = write!(
                    h,
                    "{{\"encoding\":\"csr\",\"rows\":{rows},\"cols\":{cols},\"nnz\":{},\
                     \"prune\":{}}}",
                    m.nnz(),
                    fnum(blob.prune_factor())?
                );
            }
            LayerBlob::CsrDelta(m) => {
                let _ = write!(
                    h,
                    "{{\"encoding\":\"csr_delta\",\"rows\":{rows},\"cols\":{cols},\"nnz\":{},\
                     \"payload\":{},\"prune\":{}}}",
                    m.nnz(),
                    encoding::encode_columns(m).len(),
                    fnum(blob.prune_factor())?
                );
            }
            LayerBlob::Codebook(m) => {
                let _ = write!(
                    h,
                    "{{\"encoding\":\"codebook\",\"rows\":{rows},\"cols\":{cols},\"nnz\":{},\
                     \"payload\":{},\"prune\":{}}}",
                    m.nnz(),
                    encoding::encode_columns(&m.to_csr()).len(),
                    fnum(blob.prune_factor())?
                );
            }
        }
    }
    h.push_str("]}");
    Ok(h)
}

/// Serialize to the `.rpz` container.
pub fn save_artifact(path: &Path, model: &CompressedModel) -> Result<()> {
    model.validate()?;
    let header = render_header(model)?;
    let mut body = Vec::with_capacity(header.len() + model.stored_bytes() + 8);
    put_u32(&mut body, header.len() as u32);
    body.extend_from_slice(header.as_bytes());
    for (j, blob) in model.layers.iter().enumerate() {
        match blob {
            LayerBlob::Dense(m) => {
                for &v in &m.data {
                    ensure!(
                        (Q78_MIN..=Q78_MAX).contains(&v),
                        "layer {j}: weight {v} outside the Q7.8 (i16) range"
                    );
                    body.extend_from_slice(&(v as i16).to_le_bytes());
                }
            }
            LayerBlob::Csr(m) => {
                u32_field(j, "nnz", m.nnz())?;
                for &p in m.row_ptr() {
                    put_u32(&mut body, u32_field(j, "row_ptr", p)?);
                }
                for o in 0..m.rows() {
                    let (idx, _) = m.row(o);
                    for &c in idx {
                        put_u32(&mut body, c);
                    }
                }
                for o in 0..m.rows() {
                    let (_, vals) = m.row(o);
                    for &v in vals {
                        ensure!(
                            (Q78_MIN..=Q78_MAX).contains(&v),
                            "layer {j}: weight {v} outside the Q7.8 (i16) range"
                        );
                        body.extend_from_slice(&(v as i16).to_le_bytes());
                    }
                }
            }
            LayerBlob::CsrDelta(m) => {
                u32_field(j, "nnz", m.nnz())?;
                for &p in m.row_ptr() {
                    put_u32(&mut body, u32_field(j, "row_ptr", p)?);
                }
                body.extend_from_slice(&encoding::encode_columns(m));
                for &v in m.vals() {
                    ensure!(
                        (Q78_MIN..=Q78_MAX).contains(&v),
                        "layer {j}: weight {v} outside the Q7.8 (i16) range"
                    );
                    body.extend_from_slice(&(v as i16).to_le_bytes());
                }
            }
            LayerBlob::Codebook(m) => {
                u32_field(j, "nnz", m.nnz())?;
                for &p in m.row_ptr() {
                    put_u32(&mut body, u32_field(j, "row_ptr", p)?);
                }
                body.extend_from_slice(&encoding::encode_columns(&m.to_csr()));
                for &v in m.lut() {
                    ensure!(
                        (Q78_MIN..=Q78_MAX).contains(&v),
                        "layer {j}: codebook level {v} outside the Q7.8 (i16) range"
                    );
                    body.extend_from_slice(&(v as i16).to_le_bytes());
                }
                body.extend_from_slice(&encoding::pack_nibbles(m.codes()));
            }
        }
    }
    let crc = crc32(&body);
    let mut f = BufWriter::new(
        File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&body)?;
    f.write_all(&crc.to_le_bytes())?;
    // explicit: a flush error swallowed by BufWriter's Drop would report
    // a truncated artifact as a successful save
    f.flush().with_context(|| format!("flush {}", path.display()))?;
    Ok(())
}

fn spec_from_header(h: &Json) -> Result<NetworkSpec> {
    let name = h.req("network")?.as_str()?.to_string();
    let sizes = h.req("sizes")?.as_usize_vec()?;
    ensure!(sizes.len() >= 2, "implausible architecture {sizes:?}");
    let activations = h
        .req("activations")?
        .as_str_vec()?
        .iter()
        .map(|s| Activation::from_name(s))
        .collect::<Result<Vec<_>>>()?;
    ensure!(
        activations.len() == sizes.len() - 1,
        "{} activations for {} weight matrices",
        activations.len(),
        sizes.len() - 1
    );
    Ok(NetworkSpec {
        name,
        sizes,
        activations,
    })
}

/// Read and validate a stored row-pointer array (shared by every sparse
/// layer arm): endpoints must agree with `nnz`, and it must be monotone.
fn read_row_ptr(c: &mut Cursor<'_>, j: usize, rows: usize, nnz: usize) -> Result<Vec<usize>> {
    let mut row_ptr = Vec::with_capacity(rows + 1);
    for _ in 0..rows + 1 {
        row_ptr.push(c.u32()? as usize);
    }
    ensure!(
        row_ptr.first() == Some(&0) && row_ptr.last() == Some(&nnz),
        "layer {j}: row_ptr endpoints disagree with nnz {nnz}"
    );
    ensure!(
        row_ptr.windows(2).all(|w| w[0] <= w[1]),
        "layer {j}: row_ptr not monotone"
    );
    Ok(row_ptr)
}

/// Load and validate a `.rpz` container.
pub fn load_artifact(path: &Path) -> Result<CompressedModel> {
    let mut raw = Vec::new();
    BufReader::new(File::open(path).with_context(|| format!("open {}", path.display()))?)
        .read_to_end(&mut raw)?;
    ensure!(raw.len() > 12, "file too small");
    ensure!(&raw[..4] == MAGIC, "bad magic (not a .rpz artifact)");
    let body = &raw[4..raw.len() - 4];
    let stored_crc = u32::from_le_bytes(raw[raw.len() - 4..].try_into().unwrap());
    ensure!(crc32(body) == stored_crc, "CRC mismatch: corrupted artifact");

    let mut c = Cursor { data: body, pos: 0 };
    let header_len = c.u32()? as usize;
    let header_bytes = c.take(header_len)?;
    let header = json::parse(std::str::from_utf8(header_bytes).context("header not utf-8")?)
        .context("artifact header")?;
    let version = header.req("version")?.as_usize()?;
    ensure!(
        version >= 1 && version <= VERSION as usize,
        "unsupported version {version}"
    );
    let spec = spec_from_header(&header)?;
    let qf = header.req("qformat")?;
    let frac = qf.req("frac_bits")?.as_usize()?;
    ensure!(
        frac == FRAC_BITS as usize,
        "artifact is Q.{frac}, this build runs Q.{FRAC_BITS}"
    );
    let sparse_threshold = header.req("sparse_threshold")?.as_f64()?;
    let budget = header.req("budget")?.as_f64()?;
    let baseline_accuracy = header.req("baseline_accuracy")?.as_f64()?;
    let compressed_accuracy = header.req("compressed_accuracy")?.as_f64()?;

    let entries = header.req("layers")?.as_arr()?;
    let shapes = spec.weight_shapes();
    ensure!(
        entries.len() == shapes.len(),
        "{} layer entries for {} weight matrices",
        entries.len(),
        shapes.len()
    );
    let mut layers = Vec::with_capacity(entries.len());
    for (j, (entry, &(o, i))) in entries.iter().zip(shapes.iter()).enumerate() {
        let rows = entry.req("rows")?.as_usize()?;
        let cols = entry.req("cols")?.as_usize()?;
        ensure!(
            (rows, cols) == (o, i),
            "layer {j}: stored shape ({rows},{cols}) != spec ({o},{i})"
        );
        // size every allocation from checked arithmetic bounded by the
        // bytes actually left in the file, so a crafted header claiming
        // absurd dimensions gets a clean error instead of an OOM/panic
        let remaining = body.len() - c.pos;
        match entry.req("encoding")?.as_str()? {
            "dense" => {
                let n_bytes = rows
                    .checked_mul(cols)
                    .and_then(|n| n.checked_mul(2))
                    .filter(|&n| n <= remaining)
                    .with_context(|| format!("layer {j}: dense payload exceeds file size"))?;
                let bytes = c.take(n_bytes)?;
                let data: Vec<i32> = bytes
                    .chunks_exact(2)
                    .map(|ch| i32::from(i16::from_le_bytes(ch.try_into().unwrap())))
                    .collect();
                layers.push(LayerBlob::Dense(MatI::from_vec(rows, cols, data)));
            }
            "csr" => {
                let nnz = entry.req("nnz")?.as_usize()?;
                ensure!(cols <= u32::MAX as usize, "layer {j}: cols overflow u32");
                rows.checked_add(1)
                    .and_then(|r| r.checked_mul(4))
                    .and_then(|rp| nnz.checked_mul(6).and_then(|nz| rp.checked_add(nz)))
                    .filter(|&n| n <= remaining)
                    .with_context(|| format!("layer {j}: CSR payload exceeds file size"))?;
                let row_ptr = read_row_ptr(&mut c, j, rows, nnz)?;
                let mut col_idx = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    let col = c.u32()?;
                    ensure!((col as usize) < cols, "layer {j}: column {col} out of range");
                    col_idx.push(col);
                }
                // CsrMatI's kernels rely on column-sorted, duplicate-free
                // rows; its debug_asserts vanish in release, so enforce
                // the invariant here where a bad file can be rejected
                for o in 0..rows {
                    let row = &col_idx[row_ptr[o]..row_ptr[o + 1]];
                    ensure!(
                        row.windows(2).all(|w| w[0] < w[1]),
                        "layer {j}: row {o} columns not strictly increasing"
                    );
                }
                let mut vals = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    vals.push(i32::from(c.u16()? as i16));
                }
                layers.push(LayerBlob::Csr(CsrMatI::new(rows, cols, row_ptr, col_idx, vals)));
            }
            "csr_delta" => {
                let nnz = entry.req("nnz")?.as_usize()?;
                let payload = entry.req("payload")?.as_usize()?;
                ensure!(cols <= u32::MAX as usize, "layer {j}: cols overflow u32");
                rows.checked_add(1)
                    .and_then(|r| r.checked_mul(4))
                    .and_then(|rp| rp.checked_add(payload))
                    .and_then(|p| nnz.checked_mul(2).and_then(|v| p.checked_add(v)))
                    .filter(|&n| n <= remaining)
                    .with_context(|| format!("layer {j}: csr_delta payload exceeds file size"))?;
                let row_ptr = read_row_ptr(&mut c, j, rows, nnz)?;
                // decode_columns re-derives absolute indices; gaps ≥ 1 by
                // construction, so rows come back strictly increasing and
                // range-checked without a second validation pass
                let col_idx = encoding::decode_columns(c.take(payload)?, &row_ptr, cols)
                    .with_context(|| format!("layer {j}: column stream"))?;
                let mut vals = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    vals.push(i32::from(c.u16()? as i16));
                }
                layers.push(LayerBlob::CsrDelta(CsrMatI::new(
                    rows, cols, row_ptr, col_idx, vals,
                )));
            }
            "codebook" => {
                let nnz = entry.req("nnz")?.as_usize()?;
                let payload = entry.req("payload")?.as_usize()?;
                ensure!(cols <= u32::MAX as usize, "layer {j}: cols overflow u32");
                rows.checked_add(1)
                    .and_then(|r| r.checked_mul(4))
                    .and_then(|rp| rp.checked_add(payload))
                    .and_then(|p| p.checked_add(32))
                    .and_then(|p| p.checked_add(nnz.div_ceil(2)))
                    .filter(|&n| n <= remaining)
                    .with_context(|| format!("layer {j}: codebook payload exceeds file size"))?;
                let row_ptr = read_row_ptr(&mut c, j, rows, nnz)?;
                let col_idx = encoding::decode_columns(c.take(payload)?, &row_ptr, cols)
                    .with_context(|| format!("layer {j}: column stream"))?;
                let mut lut = [0i32; 16];
                for l in lut.iter_mut() {
                    *l = i32::from(c.u16()? as i16);
                }
                let codes = encoding::unpack_nibbles(c.take(nnz.div_ceil(2))?, nnz)?;
                layers.push(LayerBlob::Codebook(CsrCodebookMatI::new(
                    rows, cols, row_ptr, col_idx, codes, lut,
                )));
            }
            other => bail!("layer {j}: unknown encoding {other:?}"),
        }
    }
    ensure!(c.pos == body.len(), "trailing bytes in artifact");
    let model = CompressedModel {
        spec,
        sparse_threshold,
        budget,
        baseline_accuracy,
        compressed_accuracy,
        layers,
    };
    model.validate()?;
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::random_qnet;
    use crate::compress::prune_qnetwork;
    use crate::nn::spec::quickstart;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("zdnn_test_rpz");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample(threshold: f64) -> CompressedModel {
        let net = prune_qnetwork(&random_qnet(&quickstart(), 11), 0.9);
        CompressedModel::from_network(&net, threshold, 0.02, 0.91, 0.9).unwrap()
    }

    #[test]
    fn threshold_decides_encoding() {
        let sparse = sample(0.75);
        assert!(sparse
            .layers
            .iter()
            .all(|b| matches!(b, LayerBlob::CsrDelta(_))));
        let dense = sample(2.0);
        assert!(dense
            .layers
            .iter()
            .all(|b| matches!(b, LayerBlob::Dense(_))));
        // compressed CSR payload beats dense storage at q = 0.9
        assert!(sparse.stored_bytes() < dense.stored_bytes());
        assert!(sparse.compression_ratio() < 1.0);
        // and the delta encoding beats the raw v1 CSR bytes
        assert!(sparse.stored_bytes() < sparse.raw_stored_bytes());
    }

    #[test]
    fn roundtrip_bit_exact_all_encodings() {
        let net = prune_qnetwork(&random_qnet(&quickstart(), 11), 0.9);
        for (name, threshold, enc) in [
            ("rt_raw.rpz", 0.75, ArtifactEncoding::Raw),
            ("rt_delta.rpz", 0.75, ArtifactEncoding::Delta),
            ("rt_cb.rpz", 0.75, ArtifactEncoding::Codebook),
            ("rt_dense.rpz", 2.0, ArtifactEncoding::Delta),
        ] {
            let model =
                CompressedModel::from_network_encoded(&net, threshold, enc, 0.02, 0.91, 0.9)
                    .unwrap();
            // storage is always lossless w.r.t. the model it was given —
            // codebook layers carry pre-quantized values, so the blob is
            // what round-trips, not the original net
            let want = model.to_qnetwork().unwrap();
            let path = tmp(name);
            save_artifact(&path, &model).unwrap();
            let back = load_artifact(&path).unwrap();
            assert_eq!(back.spec, model.spec);
            assert!((back.sparse_threshold - threshold).abs() < 1e-12);
            assert!((back.budget - 0.02).abs() < 1e-12);
            let got = back.to_qnetwork().unwrap();
            for (a, b) in got.weights.iter().zip(want.weights.iter()) {
                assert_eq!(a.data, b.data, "{name}");
            }
            assert_eq!(back.prune_factors(), model.prune_factors());
        }
    }

    #[test]
    fn codebook_encoding_stores_weight_shared_layers() {
        // quantize first (the search's codebook rung), then package
        let net = prune_qnetwork(&random_qnet(&quickstart(), 11), 0.9);
        let q = crate::nn::forward::QNetwork::new(
            net.spec.clone(),
            net.weights.iter().map(crate::compress::encoding::codebook_quantize_matrix).collect(),
        )
        .unwrap();
        let model = CompressedModel::from_network_encoded(
            &q,
            0.75,
            ArtifactEncoding::Codebook,
            0.0,
            1.0,
            1.0,
        )
        .unwrap();
        assert!(model.layers.iter().all(|b| matches!(b, LayerBlob::Codebook(_))));
        // codebook payload beats both raw CSR and delta CSR
        let delta =
            CompressedModel::from_network_encoded(&q, 0.75, ArtifactEncoding::Delta, 0.0, 1.0, 1.0)
                .unwrap();
        assert!(model.stored_bytes() < delta.stored_bytes());
        let path = tmp("cb_shared.rpz");
        save_artifact(&path, &model).unwrap();
        let back = load_artifact(&path).unwrap();
        for (a, b) in
            back.to_qnetwork().unwrap().weights.iter().zip(q.weights.iter())
        {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn overflow_error_is_typed_not_truncated() {
        let e = u32_field(3, "row_ptr", u32::MAX as usize + 1).unwrap_err();
        assert!(
            e.to_string().contains("layer 3")
                && e.to_string().contains("row_ptr")
                && e.to_string().contains("overflows the u32"),
            "{e}"
        );
        assert_eq!(u32_field(0, "nnz", u32::MAX as usize).unwrap(), u32::MAX);
        let typed = IndexOverflowError {
            layer: 1,
            field: "nnz",
            value: usize::MAX,
        };
        // goes through the blanket std::error::Error conversion
        let chained: anyhow::Error = typed.clone().into();
        assert_eq!(chained.to_string(), typed.to_string());
    }

    #[test]
    fn corruption_and_bad_magic_rejected() {
        let path = tmp("corrupt.rpz");
        save_artifact(&path, &sample(0.75)).unwrap();
        let mut raw = std::fs::read(&path).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0xFF;
        std::fs::write(&path, &raw).unwrap();
        assert!(load_artifact(&path).is_err());
        std::fs::write(&path, b"NOPEnope123456789012").unwrap();
        assert!(load_artifact(&path).is_err());
    }

    #[test]
    fn mixed_encoding_from_per_layer_factors() {
        // layer 0 pruned hard, layer 1 untouched: one CSR, one dense blob
        let net = random_qnet(&quickstart(), 12);
        let mixed = crate::compress::prune_per_layer(&net, &[0.9, 0.0]);
        let model = CompressedModel::from_network(&mixed, 0.75, 0.0, 1.0, 1.0).unwrap();
        assert!(matches!(model.layers[0], LayerBlob::CsrDelta(_)));
        assert!(matches!(model.layers[1], LayerBlob::Dense(_)));
        let path = tmp("mixed.rpz");
        save_artifact(&path, &model).unwrap();
        let back = load_artifact(&path).unwrap();
        let got = back.to_qnetwork().unwrap();
        for (a, b) in got.weights.iter().zip(mixed.weights.iter()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn non_finite_metadata_rejected() {
        let net = random_qnet(&quickstart(), 13);
        assert!(CompressedModel::from_network(&net, f64::INFINITY, 0.0, 1.0, 1.0).is_err());
        let mut model = CompressedModel::from_network(&net, 0.75, 0.0, 1.0, 1.0).unwrap();
        model.budget = f64::NAN;
        assert!(save_artifact(&tmp("nan.rpz"), &model).is_err());
    }
}
