//! Magnitude pruning on the Q7.8 grid — the one shared implementation
//! behind the simulator utilities, the compression pipeline, and the
//! benches (it used to live in `sim::pruning`, which still re-exports
//! [`prune_qnetwork`] for its callers).
//!
//! Semantics (paper §4.3): for a target factor `q`, δ is the magnitude of
//! the ⌊n·q⌋-th smallest weight and every weight with |w| ≤ δ is set to
//! zero.  Ties at δ are all pruned, so the achieved factor can slightly
//! exceed the target — that is the measured `q_prune` the plan compiler
//! and the timing simulator both consume.  `q ≤ 0` is the identity (no
//! δ, nothing pruned), which is what the per-layer search relies on for
//! its "layer untouched" starting point.

use crate::nn::forward::QNetwork;
use crate::tensor::MatI;

/// Zero the smallest-magnitude entries of one Q7.8 weight matrix in
/// place, targeting a fraction `q_prune` of zeros.
pub fn prune_matrix(w: &mut MatI, q_prune: f64) {
    if q_prune <= 0.0 || w.data.is_empty() {
        return;
    }
    let mut mags: Vec<i32> = w.data.iter().map(|v| v.abs()).collect();
    mags.sort_unstable();
    let idx = ((mags.len() as f64 * q_prune).floor() as usize).min(mags.len() - 1);
    let delta = mags[idx];
    for v in w.data.iter_mut() {
        if v.abs() <= delta {
            *v = 0;
        }
    }
}

/// Prune every layer of a quantized network to the same target factor
/// *post-hoc* (utility for benches that need a given q_prune without a
/// full retraining run; accuracy-carrying paths use `train::prune` or the
/// budgeted search in [`crate::compress::search`]).
pub fn prune_qnetwork(net: &QNetwork, q_prune: f64) -> QNetwork {
    let mut pruned = net.clone();
    for w in pruned.weights.iter_mut() {
        prune_matrix(w, q_prune);
    }
    pruned
}

/// Prune a single layer transition, leaving every other layer untouched
/// (the sensitivity sweep's probe, and the budgeted search's move).
pub fn prune_layer(net: &QNetwork, layer: usize, q_prune: f64) -> QNetwork {
    let mut pruned = net.clone();
    prune_matrix(&mut pruned.weights[layer], q_prune);
    pruned
}

/// Apply one target factor per layer transition (the budgeted search's
/// final assignment re-applied from scratch).
pub fn prune_per_layer(net: &QNetwork, factors: &[f64]) -> QNetwork {
    assert_eq!(
        factors.len(),
        net.weights.len(),
        "one prune factor per layer transition"
    );
    let mut pruned = net.clone();
    for (w, &q) in pruned.weights.iter_mut().zip(factors.iter()) {
        prune_matrix(w, q);
    }
    pruned
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::random_qnet;
    use crate::nn::spec::quickstart;

    #[test]
    fn zero_target_is_identity() {
        let net = random_qnet(&quickstart(), 1);
        let p = prune_qnetwork(&net, 0.0);
        for (a, b) in p.weights.iter().zip(net.weights.iter()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn reaches_target_factor() {
        let net = random_qnet(&quickstart(), 2);
        for q in [0.5, 0.8, 0.94] {
            let f = prune_qnetwork(&net, q).overall_prune_factor();
            assert!(f >= q - 0.02, "target {q}, achieved {f}");
        }
    }

    #[test]
    fn prune_layer_touches_only_that_layer() {
        let net = random_qnet(&quickstart(), 3);
        let p = prune_layer(&net, 1, 0.9);
        assert_eq!(p.weights[0].data, net.weights[0].data);
        let f = p.prune_factors();
        assert!(f[1] >= 0.88, "{f:?}");
    }

    #[test]
    fn per_layer_factors_apply_independently() {
        let net = random_qnet(&quickstart(), 4);
        let p = prune_per_layer(&net, &[0.9, 0.0]);
        let f = p.prune_factors();
        assert!(f[0] >= 0.88, "{f:?}");
        assert_eq!(p.weights[1].data, net.weights[1].data);
    }

    #[test]
    fn monotone_in_target() {
        let net = random_qnet(&quickstart(), 5);
        let f50 = prune_qnetwork(&net, 0.5).overall_prune_factor();
        let f90 = prune_qnetwork(&net, 0.9).overall_prune_factor();
        assert!(f90 >= f50, "{f50} {f90}");
    }
}
