//! Accuracy-budgeted per-layer prune search.
//!
//! Greedy over layers ordered least-sensitive first (from the
//! [`SensitivityReport`]): for each layer, scan the ladder from the most
//! aggressive rung down and keep the first one whose *measured*
//! end-to-end accuracy — with every previously accepted layer still
//! pruned — stays at or above `baseline − budget`.  A rung is only ever
//! accepted after evaluation, so the outcome can never exceed the budget
//! on the search slice, whatever the interactions between layers do
//! (accuracy under pruning is not monotone, which is also why this scans
//! the ladder instead of binary-searching it).
//!
//! With `encoding = Codebook` a second greedy pass follows the prune
//! pass: layers are codebook-quantized (16-level deterministic k-means,
//! EIE's weight sharing) one at a time, least codebook-sensitive first,
//! each move again accepted only if the *measured* accuracy stays at or
//! above the same floor — so the one budget covers both pruning and
//! quantization error, by construction.

use anyhow::{ensure, Result};

use super::encoding::{codebook_quantize_matrix, ArtifactEncoding};
use super::prune::prune_layer;
use super::sensitivity::{codebook_deltas, SensitivityReport};
use super::{accuracy_q, EvalSet};
use crate::nn::forward::QNetwork;

/// Search knobs.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Maximum tolerated accuracy drop vs the dense baseline (absolute,
    /// e.g. `0.02` = two points).
    pub budget: f64,
    /// Candidate per-layer prune factors, ascending.
    pub ladder: Vec<f64>,
    /// Target artifact encoding.  `Codebook` enables the weight-sharing
    /// pass; `Raw`/`Delta` only affect how the artifact stores the result
    /// (both lossless).
    pub encoding: ArtifactEncoding,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            budget: 0.02,
            ladder: super::sensitivity::DEFAULT_LADDER.to_vec(),
            encoding: ArtifactEncoding::Delta,
        }
    }
}

/// What the search found.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Dense-baseline accuracy on the search slice.
    pub baseline_accuracy: f64,
    /// Measured accuracy of the final pruned network on the same slice.
    pub compressed_accuracy: f64,
    /// The budget the search ran with.
    pub budget: f64,
    /// Chosen *target* factor per layer (0.0 = layer left dense).
    pub factors: Vec<f64>,
    /// Measured per-layer prune factors of the result (zeros fraction).
    pub achieved: Vec<f64>,
    /// The encoding the search ran with (what the artifact will store).
    pub encoding: ArtifactEncoding,
    /// Which layers the codebook pass accepted (all `false` unless
    /// `encoding == Codebook`).
    pub codebook: Vec<bool>,
    /// The pruned (and possibly weight-shared) network itself.
    pub network: QNetwork,
}

impl SearchOutcome {
    /// Overall measured prune factor of the compressed network.
    pub fn overall_prune(&self) -> f64 {
        self.network.overall_prune_factor()
    }

    /// Measured accuracy drop (positive = worse than baseline).
    pub fn accuracy_delta(&self) -> f64 {
        self.baseline_accuracy - self.compressed_accuracy
    }
}

/// Run the budgeted search.  `report` must come from a sweep over the
/// same network (it provides the layer ordering and the baseline).
pub fn search(
    net: &QNetwork,
    eval: &EvalSet,
    report: &SensitivityReport,
    cfg: &SearchConfig,
) -> Result<SearchOutcome> {
    ensure!(cfg.budget >= 0.0, "budget must be >= 0, got {}", cfg.budget);
    ensure!(!cfg.ladder.is_empty(), "search ladder must not be empty");
    // an empty slice scores 0.0 for everything, which would "hold" any
    // budget while pruning every layer to the top rung unmeasured
    ensure!(!eval.is_empty(), "search eval slice must not be empty");
    ensure!(
        report.layers() == net.weights.len(),
        "sensitivity report covers {} layers, network has {}",
        report.layers(),
        net.weights.len()
    );
    let mut ladder = cfg.ladder.clone();
    ladder.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));

    let baseline = accuracy_q(net, eval)?;
    let floor = baseline - cfg.budget;
    let mut factors = vec![0.0f64; net.weights.len()];
    let mut current = net.clone();
    let mut current_acc = baseline;
    for layer in report.layers_by_sensitivity() {
        for &q in ladder.iter().rev() {
            let candidate = prune_layer(&current, layer, q);
            let acc = accuracy_q(&candidate, eval)?;
            if acc >= floor {
                factors[layer] = q;
                current = candidate;
                current_acc = acc;
                break;
            }
        }
    }
    // codebook pass: same floor, same accept-only-after-measuring greedy,
    // ordered by the quantization sensitivity of the *pruned* network
    let mut codebook = vec![false; net.weights.len()];
    if cfg.encoding == ArtifactEncoding::Codebook {
        let deltas = codebook_deltas(&current, eval)?;
        let mut order: Vec<usize> = (0..deltas.len()).collect();
        order.sort_by(|&a, &b| {
            deltas[a]
                .partial_cmp(&deltas[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        for layer in order {
            let mut candidate = current.clone();
            candidate.weights[layer] = codebook_quantize_matrix(&candidate.weights[layer]);
            let acc = accuracy_q(&candidate, eval)?;
            if acc >= floor {
                codebook[layer] = true;
                current = candidate;
                current_acc = acc;
            }
        }
    }
    let achieved = current.prune_factors();
    Ok(SearchOutcome {
        baseline_accuracy: baseline,
        compressed_accuracy: current_acc,
        budget: cfg.budget,
        factors,
        achieved,
        encoding: cfg.encoding,
        codebook,
        network: current,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::random_qnet;
    use crate::compress::sensitivity::sweep;
    use crate::compress::EvalSet;
    use crate::data::har;
    use crate::nn::spec::NetworkSpec;

    fn fixture(seed: u64) -> (QNetwork, EvalSet) {
        let spec = NetworkSpec::new("t", &[561, 16, 6]);
        (
            random_qnet(&spec, seed),
            EvalSet::from_dataset(&har::generate(60, seed ^ 0xE)),
        )
    }

    fn run_enc(seed: u64, budget: f64, encoding: ArtifactEncoding) -> SearchOutcome {
        let (net, eval) = fixture(seed);
        let report = sweep(&net, &eval, &[0.5, 0.8, 0.95]).unwrap();
        let cfg = SearchConfig {
            budget,
            ladder: vec![0.5, 0.8, 0.95],
            encoding,
        };
        search(&net, &eval, &report, &cfg).unwrap()
    }

    fn run(seed: u64, budget: f64) -> SearchOutcome {
        run_enc(seed, budget, ArtifactEncoding::Delta)
    }

    #[test]
    fn never_exceeds_budget_and_reports_consistently() {
        for seed in [1, 2, 3] {
            for budget in [0.0, 0.02, 0.10] {
                let o = run(seed, budget);
                assert!(
                    o.accuracy_delta() <= budget + 1e-12,
                    "seed {seed} budget {budget}: delta {}",
                    o.accuracy_delta()
                );
                // the reported accuracy is the measured accuracy of the
                // returned network, not a stale intermediate
                let eval = fixture(seed).1;
                let measured = accuracy_q(&o.network, &eval).unwrap();
                assert!((measured - o.compressed_accuracy).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn infinite_budget_prunes_everything_to_the_top_rung() {
        let o = run(4, 1.0);
        assert!(o.factors.iter().all(|&q| (q - 0.95).abs() < 1e-12), "{:?}", o.factors);
        assert!(o.overall_prune() >= 0.9);
    }

    #[test]
    fn codebook_rung_holds_budget_and_marks_layers() {
        for (seed, budget) in [(1u64, 0.02), (2, 0.10), (3, 1.0)] {
            let o = run_enc(seed, budget, ArtifactEncoding::Codebook);
            assert!(
                o.accuracy_delta() <= budget + 1e-12,
                "seed {seed} budget {budget}: delta {}",
                o.accuracy_delta()
            );
            assert_eq!(o.encoding, ArtifactEncoding::Codebook);
            assert_eq!(o.codebook.len(), o.network.weights.len());
            // every accepted layer really is 16-level representable
            for (layer, &accepted) in o.codebook.iter().enumerate() {
                if accepted {
                    let mut d: Vec<i32> = o.network.weights[layer]
                        .data
                        .iter()
                        .copied()
                        .filter(|&v| v != 0)
                        .collect();
                    d.sort_unstable();
                    d.dedup();
                    assert!(d.len() <= 16, "layer {layer}: {} levels", d.len());
                }
            }
            // an infinite budget accepts the codebook everywhere
            if budget >= 1.0 {
                assert!(o.codebook.iter().all(|&c| c), "{:?}", o.codebook);
            }
        }
        // lossless encodings never mark codebook layers
        assert!(run(4, 0.1).codebook.iter().all(|&c| !c));
    }

    #[test]
    fn rejects_bad_inputs() {
        let (net, eval) = fixture(5);
        let report = sweep(&net, &eval, &[0.5]).unwrap();
        let bad = SearchConfig {
            budget: -0.1,
            ladder: vec![0.5],
            encoding: ArtifactEncoding::Delta,
        };
        assert!(search(&net, &eval, &report, &bad).is_err());
        let empty = SearchConfig {
            budget: 0.1,
            ladder: vec![],
            encoding: ArtifactEncoding::Delta,
        };
        assert!(search(&net, &eval, &report, &empty).is_err());
        let no_eval = EvalSet {
            x: crate::tensor::MatI::zeros(0, 561),
            y: vec![],
        };
        assert!(search(&net, &no_eval, &report, &SearchConfig::default()).is_err());
        // report from a different-depth network is rejected
        let other = random_qnet(&NetworkSpec::new("o", &[561, 8, 8, 6]), 6);
        assert!(search(&other, &eval, &report, &SearchConfig::default()).is_err());
    }
}
