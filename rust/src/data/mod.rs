//! Synthetic datasets (DESIGN.md §2 substitution for MNIST / HAR).
//!
//! The paper's accuracy claims (Table 4) are about *relative* accuracy under
//! pruning, so the substitute tasks only need to (a) match the input
//! dimensionality and class counts of MNIST (784/10) and HAR (561/6) and
//! (b) be learnable-but-not-trivial for the paper's architectures.
//!
//! * `mnist`: procedural 28×28 digit glyphs — coarse 7×7 stencils per digit,
//!   upscaled with random shift/scale jitter, stroke thickness noise and
//!   pixel noise; replicates MNIST's "same class, varying pen" structure.
//! * `har`: 561-dim feature vectors drawn from class-conditional Gaussians
//!   with shared covariance structure and overlapping activity pairs
//!   (sitting/standing deliberately close, like the real sensor data).

pub mod har;
pub mod mnist;

use anyhow::{bail, Result};

use crate::tensor::MatF;

/// A labelled dataset split.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// (samples × features), values pre-scaled to roughly [-1, 1].
    pub x: MatF,
    pub y: Vec<usize>,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn features(&self) -> usize {
        self.x.cols
    }

    /// Take the first `n` samples (cheap view-copy for small benches).
    pub fn head(&self, n: usize) -> Dataset {
        let n = n.min(self.len());
        Dataset {
            x: MatF::from_vec(n, self.x.cols, self.x.data[..n * self.x.cols].to_vec()),
            y: self.y[..n].to_vec(),
            num_classes: self.num_classes,
        }
    }

    /// Per-class counts (sanity checks / stratification tests).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.num_classes];
        for &y in &self.y {
            counts[y] += 1;
        }
        counts
    }
}

/// The eval/train data matching a built-in network's input layer: synthetic
/// MNIST for `mnist*`, synthetic HAR for `har*`, and 8×8 average-pooled
/// digits for `quickstart` (64 features).  Shared by the `train` and
/// `compress` CLI paths and `bench compress`.
pub fn for_network(name: &str, n: usize, seed: u64) -> Result<Dataset> {
    if name == "quickstart" {
        let full = mnist::generate(n, seed);
        let mut x = MatF::zeros(n, 64);
        for i in 0..n {
            let row = full.x.row(i);
            for j in 0..64 {
                let (cy, cx) = (j / 8, j % 8);
                let mut sum = 0.0f32;
                let mut cnt = 0;
                for py in (cy * 28 / 8)..(((cy + 1) * 28 + 7) / 8).min(28) {
                    for px in (cx * 28 / 8)..(((cx + 1) * 28 + 7) / 8).min(28) {
                        sum += row[py * 28 + px];
                        cnt += 1;
                    }
                }
                x.set(i, j, sum / cnt.max(1) as f32);
            }
        }
        return Ok(Dataset {
            x,
            y: full.y,
            num_classes: full.num_classes,
        });
    }
    if name.starts_with("mnist") {
        Ok(mnist::generate(n, seed))
    } else if name.starts_with("har") {
        Ok(har::generate(n, seed))
    } else {
        bail!("no synthetic dataset for network {name:?}")
    }
}

/// Train/test pair, mirroring the official split sizes of the real sets.
#[derive(Debug, Clone)]
pub struct Splits {
    pub train: Dataset,
    pub test: Dataset,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_truncates() {
        let d = mnist::generate(100, 42);
        let h = d.head(10);
        assert_eq!(h.len(), 10);
        assert_eq!(h.x.rows, 10);
        assert_eq!(h.num_classes, 10);
    }

    #[test]
    fn class_counts_sum_to_len() {
        let d = har::generate(120, 7);
        assert_eq!(d.class_counts().iter().sum::<usize>(), d.len());
    }
}
