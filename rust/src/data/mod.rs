//! Synthetic datasets (DESIGN.md §2 substitution for MNIST / HAR).
//!
//! The paper's accuracy claims (Table 4) are about *relative* accuracy under
//! pruning, so the substitute tasks only need to (a) match the input
//! dimensionality and class counts of MNIST (784/10) and HAR (561/6) and
//! (b) be learnable-but-not-trivial for the paper's architectures.
//!
//! * `mnist`: procedural 28×28 digit glyphs — coarse 7×7 stencils per digit,
//!   upscaled with random shift/scale jitter, stroke thickness noise and
//!   pixel noise; replicates MNIST's "same class, varying pen" structure.
//! * `har`: 561-dim feature vectors drawn from class-conditional Gaussians
//!   with shared covariance structure and overlapping activity pairs
//!   (sitting/standing deliberately close, like the real sensor data).

pub mod har;
pub mod mnist;

use crate::tensor::MatF;

/// A labelled dataset split.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// (samples × features), values pre-scaled to roughly [-1, 1].
    pub x: MatF,
    pub y: Vec<usize>,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn features(&self) -> usize {
        self.x.cols
    }

    /// Take the first `n` samples (cheap view-copy for small benches).
    pub fn head(&self, n: usize) -> Dataset {
        let n = n.min(self.len());
        Dataset {
            x: MatF::from_vec(n, self.x.cols, self.x.data[..n * self.x.cols].to_vec()),
            y: self.y[..n].to_vec(),
            num_classes: self.num_classes,
        }
    }

    /// Per-class counts (sanity checks / stratification tests).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.num_classes];
        for &y in &self.y {
            counts[y] += 1;
        }
        counts
    }
}

/// Train/test pair, mirroring the official split sizes of the real sets.
#[derive(Debug, Clone)]
pub struct Splits {
    pub train: Dataset,
    pub test: Dataset,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_truncates() {
        let d = mnist::generate(100, 42);
        let h = d.head(10);
        assert_eq!(h.len(), 10);
        assert_eq!(h.x.rows, 10);
        assert_eq!(h.num_classes, 10);
    }

    #[test]
    fn class_counts_sum_to_len() {
        let d = har::generate(120, 7);
        assert_eq!(d.class_counts().iter().sum::<usize>(), d.len());
    }
}
