//! Synthetic HAR (human activity recognition) substitute: 561-dim feature
//! vectors, 6 classes (walking, upstairs, downstairs, sitting, standing,
//! laying — the UCI smartphone dataset classes).
//!
//! Generation model: each class has a smooth prototype spectrum (sum of a
//! few class-keyed sinusoids over the feature index, mimicking the
//! band-structured accelerometer/gyroscope features of the real set), and
//! samples add correlated noise plus a per-sample "motion energy" factor.
//! The static activities (sitting/standing) share most of their prototype,
//! reproducing the real dataset's hardest confusion pair.

use super::{Dataset, Splits};
use crate::tensor::MatF;
use crate::util::rng::Xoshiro256;

pub const FEATURES: usize = 561;
pub const CLASSES: usize = 6;

/// Class prototype value for feature `f` — deterministic, no RNG, so the
/// class structure is identical across splits and seeds.
fn prototype(class: usize, f: usize) -> f64 {
    let t = f as f64 / FEATURES as f64;
    // shared sitting/standing base: classes 3 and 4 differ only by a small
    // high-frequency component, like the real data
    let base_class = if class == 4 { 3 } else { class };
    let k1 = 2.0 + base_class as f64;
    let k2 = 7.0 + 2.0 * base_class as f64;
    let mut v = (std::f64::consts::TAU * k1 * t).sin() * 0.5
        + (std::f64::consts::TAU * k2 * t + base_class as f64).cos() * 0.3;
    // motion energy: dynamic activities (0..=2) have larger magnitude in the
    // "body acceleration" band (first third of the features)
    if base_class <= 2 && t < 0.33 {
        v += 0.4 + 0.1 * base_class as f64;
    }
    if class == 4 {
        // standing vs sitting: small gravity-axis offset in the middle band
        if (0.4..0.55).contains(&t) {
            v += 0.35;
        }
    }
    v.tanh()
}

/// Generate one sample of `class` into `out` (values roughly [-1, 1]).
pub fn render_sample(class: usize, rng: &mut Xoshiro256, out: &mut [f32]) {
    assert_eq!(out.len(), FEATURES);
    let energy = rng.uniform(0.85, 1.15);
    let drift = rng.normal_scaled(0.0, 0.05);
    // low-frequency correlated noise: random phase sinusoid
    let phase = rng.uniform(0.0, std::f64::consts::TAU);
    let noise_amp = rng.uniform(0.05, 0.15);
    for (f, o) in out.iter_mut().enumerate() {
        let t = f as f64 / FEATURES as f64;
        let corr = (std::f64::consts::TAU * 3.0 * t + phase).sin() * noise_amp;
        let v = prototype(class, f) * energy + drift + corr + rng.normal_scaled(0.0, 0.08);
        *o = v.clamp(-1.0, 1.0) as f32;
    }
}

/// Generate `n` labelled samples with shuffled class order.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut labels: Vec<usize> = (0..n).map(|i| i % CLASSES).collect();
    rng.shuffle(&mut labels);
    let mut x = MatF::zeros(n, FEATURES);
    for (i, &label) in labels.iter().enumerate() {
        render_sample(label, &mut rng, x.row_mut(i));
    }
    Dataset {
        x,
        y: labels,
        num_classes: CLASSES,
    }
}

/// Train/test splits (real HAR: 7352 train / 2947 test).
pub fn splits(train_n: usize, test_n: usize, seed: u64) -> Splits {
    Splits {
        train: generate(train_n, seed),
        test: generate(test_n, seed ^ 0x11A2),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_shape_and_range() {
        let d = generate(60, 1);
        assert_eq!(d.len(), 60);
        assert_eq!(d.features(), 561);
        assert!(d.x.data.iter().all(|&v| (-1.0..=1.0).contains(&v)));
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(generate(10, 2).x.data, generate(10, 2).x.data);
    }

    #[test]
    fn sitting_standing_closer_than_walking() {
        // verify the engineered confusion structure: proto(3) vs proto(4)
        // distance must be well below proto(3) vs proto(0)
        let dist = |a: usize, b: usize| -> f64 {
            (0..FEATURES)
                .map(|f| (prototype(a, f) - prototype(b, f)).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        assert!(dist(3, 4) < 0.5 * dist(3, 0));
    }

    #[test]
    fn classes_separable_by_nearest_prototype() {
        let test = generate(240, 3);
        let mut correct = 0;
        for i in 0..test.len() {
            let row = test.x.row(i);
            let best = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f64 = row
                        .iter()
                        .enumerate()
                        .map(|(f, &v)| (f64::from(v) - prototype(a, f)).powi(2))
                        .sum();
                    let db: f64 = row
                        .iter()
                        .enumerate()
                        .map(|(f, &v)| (f64::from(v) - prototype(b, f)).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.7, "nearest-prototype accuracy too low: {acc}");
    }
}
