//! Procedural MNIST substitute: 28×28 grayscale digit glyphs.
//!
//! Each digit has a 7×7 coarse stencil (hand-drawn below).  A sample is
//! produced by upscaling the stencil 4× with bilinear smoothing, then
//! applying per-sample jitter: sub-pixel translation, scale, stroke
//! intensity, and additive noise.  The result keeps MNIST's key properties
//! for our purposes: 784 inputs in [0, 1], 10 classes, within-class
//! variation that a 784×800×800×10 MLP fits well but not trivially.

use super::{Dataset, Splits};
use crate::tensor::MatF;
use crate::util::rng::Xoshiro256;

pub const SIDE: usize = 28;
pub const FEATURES: usize = SIDE * SIDE;
pub const CLASSES: usize = 10;

/// 7×7 stencils, rows top-to-bottom ('#' = stroke).
const STENCILS: [[&str; 7]; 10] = [
    [" ### ", "#   #", "#   #", "#   #", "#   #", "#   #", " ### "], // 0
    ["  #  ", " ##  ", "  #  ", "  #  ", "  #  ", "  #  ", " ### "], // 1
    [" ### ", "#   #", "    #", "   # ", "  #  ", " #   ", "#####"], // 2
    [" ### ", "#   #", "    #", "  ## ", "    #", "#   #", " ### "], // 3
    ["   # ", "  ## ", " # # ", "#  # ", "#####", "   # ", "   # "], // 4
    ["#####", "#    ", "#### ", "    #", "    #", "#   #", " ### "], // 5
    [" ### ", "#    ", "#    ", "#### ", "#   #", "#   #", " ### "], // 6
    ["#####", "    #", "   # ", "  #  ", "  #  ", " #   ", " #   "], // 7
    [" ### ", "#   #", "#   #", " ### ", "#   #", "#   #", " ### "], // 8
    [" ### ", "#   #", "#   #", " ####", "    #", "    #", " ### "], // 9
];

const STENCIL_W: usize = 5;
const STENCIL_H: usize = 7;

/// Sample the stencil at continuous coordinates with bilinear filtering.
fn stencil_at(digit: usize, u: f64, v: f64) -> f64 {
    if !(0.0..1.0).contains(&u) || !(0.0..1.0).contains(&v) {
        return 0.0;
    }
    let x = u * (STENCIL_W as f64) - 0.5;
    let y = v * (STENCIL_H as f64) - 0.5;
    let x0 = x.floor();
    let y0 = y.floor();
    let fx = x - x0;
    let fy = y - y0;
    let sample = |ix: i64, iy: i64| -> f64 {
        if ix < 0 || iy < 0 || ix >= STENCIL_W as i64 || iy >= STENCIL_H as i64 {
            return 0.0;
        }
        let row = STENCILS[digit][iy as usize].as_bytes();
        if row[ix as usize] == b'#' {
            1.0
        } else {
            0.0
        }
    };
    let x0i = x0 as i64;
    let y0i = y0 as i64;
    sample(x0i, y0i) * (1.0 - fx) * (1.0 - fy)
        + sample(x0i + 1, y0i) * fx * (1.0 - fy)
        + sample(x0i, y0i + 1) * (1.0 - fx) * fy
        + sample(x0i + 1, y0i + 1) * fx * fy
}

/// Render one jittered digit into a 784-float buffer (values in [0, 1]).
pub fn render_digit(digit: usize, rng: &mut Xoshiro256, out: &mut [f32]) {
    assert_eq!(out.len(), FEATURES);
    let dx = rng.uniform(-0.08, 0.08);
    let dy = rng.uniform(-0.08, 0.08);
    let scale = rng.uniform(0.85, 1.15);
    let intensity = rng.uniform(0.75, 1.0);
    let noise = rng.uniform(0.02, 0.08);
    let smear = rng.uniform(0.0, 0.35); // stroke softness
    for py in 0..SIDE {
        for px in 0..SIDE {
            // normalized coords with jitter, glyph centered in a margin
            let u = ((px as f64 + 0.5) / SIDE as f64 - 0.5 - dx) / scale + 0.5;
            let v = ((py as f64 + 0.5) / SIDE as f64 - 0.5 - dy) / scale + 0.5;
            let mut val = stencil_at(digit, u, v);
            // soften strokes: mix with a half-pixel-offset sample
            if smear > 0.0 {
                let off = 0.5 / SIDE as f64;
                val = (1.0 - smear) * val + smear * stencil_at(digit, u + off, v + off);
            }
            let val = (val * intensity + rng.normal_scaled(0.0, noise)).clamp(0.0, 1.0);
            out[py * SIDE + px] = val as f32;
        }
    }
}

/// Generate `n` labelled samples (labels cycle through the classes so every
/// class is represented; order is then shuffled).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut labels: Vec<usize> = (0..n).map(|i| i % CLASSES).collect();
    rng.shuffle(&mut labels);
    let mut x = MatF::zeros(n, FEATURES);
    for (i, &label) in labels.iter().enumerate() {
        render_digit(label, &mut rng, x.row_mut(i));
    }
    Dataset {
        x,
        y: labels,
        num_classes: CLASSES,
    }
}

/// Standard splits, scaled-down proportions of the real MNIST 60k/10k.
pub fn splits(train_n: usize, test_n: usize, seed: u64) -> Splits {
    Splits {
        train: generate(train_n, seed),
        test: generate(test_n, seed ^ 0x7E57),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_and_range() {
        let d = generate(50, 1);
        assert_eq!(d.len(), 50);
        assert_eq!(d.features(), 784);
        assert!(d.x.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn all_classes_present() {
        let d = generate(40, 2);
        let counts = d.class_counts();
        assert!(counts.iter().all(|&c| c >= 1), "{counts:?}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(20, 3);
        let b = generate(20, 3);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.y, b.y);
        let c = generate(20, 4);
        assert_ne!(a.x.data, c.x.data);
    }

    #[test]
    fn within_class_variation_exists() {
        // two samples of the same digit must differ (jitter + noise)
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut a = vec![0f32; FEATURES];
        let mut b = vec![0f32; FEATURES];
        render_digit(3, &mut rng, &mut a);
        render_digit(3, &mut rng, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn classes_are_separable_by_template_matching() {
        // nearest-mean classifier on clean means should beat 60% easily;
        // this guards against degenerate (unlearnable) generation
        let train = generate(500, 6);
        let test = generate(200, 7);
        let mut means = vec![vec![0f64; FEATURES]; CLASSES];
        let mut counts = vec![0usize; CLASSES];
        for i in 0..train.len() {
            let y = train.y[i];
            counts[y] += 1;
            for (m, &v) in means[y].iter_mut().zip(train.x.row(i)) {
                *m += f64::from(v);
            }
        }
        for (m, &c) in means.iter_mut().zip(counts.iter()) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let row = test.x.row(i);
            let best = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f64 = row
                        .iter()
                        .zip(&means[a])
                        .map(|(&v, &m)| (f64::from(v) - m).powi(2))
                        .sum();
                    let db: f64 = row
                        .iter()
                        .zip(&means[b])
                        .map(|(&v, &m)| (f64::from(v) - m).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.6, "template-matching accuracy too low: {acc}");
    }
}
