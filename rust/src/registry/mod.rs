//! Multi-model registry: many named, versioned `.rpz` artifacts fronting
//! the sharded serving pool, with per-model routing and zero-downtime hot
//! swap.
//!
//! Each registered model owns a warm replica set — a
//! [`ServePool`](crate::serve::ServePool) whose worker count is sized
//! from the model's configured traffic share — compiled once via the
//! plan-replication path ([`ExecPlan::compile_artifact`] +
//! [`clone_shared`](crate::exec::ExecPlan::clone_shared)) like any
//! single-model pool.  All pools share one request-id counter and one
//! trace ring, so the PR 4–5 ticket/wire machinery (tagged pipelining,
//! one demux per connection, `TRACE #<id>`) works unchanged: the
//! registry is just another [`SubmitTarget`] that routes by model name
//! before handing the request to a pool.
//!
//! Hot swap ([`Registry::swap`]) is the headline semantics:
//!
//! 1. **Warm off-path** — the new version's artifact is loaded and its
//!    replica set compiled on the caller thread; the serving map is
//!    untouched, so live traffic never sees a cold replica.
//! 2. **Atomic flip** — the registry entry is replaced under a write
//!    lock; every submission after the flip lands on the new version.
//! 3. **Drain** — the old replica set is shut down gracefully: shard
//!    shutdown force-drains queued batches (see
//!    [`executor_loop`](crate::coordinator::executor::executor_loop)),
//!    so in-flight and already-queued requests complete on the old
//!    version.  Nothing is dropped and nothing is double-replied; the
//!    swap call returns only after the drain finishes.
//!
//! A submission racing the flip can catch the old pool mid-shutdown;
//! [`Registry::submit_to`] retries against the re-read map (which
//! already holds the new entry), so the race resolves to "served by the
//! new version" instead of a spurious rejection.
//!
//! Admission quotas ride the same shares: each model's pool gets
//! `max(batch, share × queue_depth)` queue slots, so one model's burst
//! saturates its own quota and bounces — it cannot crowd the other
//! models out of the shared frontend.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{ModelSpec, ServerConfig};
use crate::coordinator::engine::EngineFactory;
use crate::coordinator::net::{StatsReport, SubmitTarget};
use crate::coordinator::request::{Priority, Reply, RequestId};
use crate::obs::registry::Registry as MetricsRegistry;
use crate::obs::trace::{TraceRing, TRACE_RING_CAPACITY};
use crate::serve::{PoolHandle, ServePool, ShardMetrics};

/// One registered model version: a named warm replica set.
///
/// Entries are immutable once published — a swap builds a *new* entry
/// and flips the map pointer, so readers never observe a half-updated
/// model.  No `Drop` impl: the swap path moves the pool out for a
/// graceful drain.
pub struct ModelEntry {
    pub name: String,
    /// Monotonic per-model version, bumped by every successful swap.
    pub version: u64,
    /// Artifact path this version was loaded from.
    pub path: String,
    /// Relative traffic weight (from the config `models` key).
    pub share: f64,
    replicas: usize,
    pool: PoolHandle,
    requests: AtomicU64,
}

impl ModelEntry {
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Requests this version has accepted.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }
}

/// Summary a completed hot swap returns (after the old version's drain).
#[derive(Debug, Clone)]
pub struct SwapReport {
    pub model: String,
    pub old_version: u64,
    pub new_version: u64,
    pub replicas: usize,
    /// Requests the old version served over its lifetime (all of them —
    /// the drain completes before the swap returns).
    pub drained_requests: u64,
}

impl SwapReport {
    /// Wire form for the `SWAP` admin reply.
    pub fn render(&self) -> String {
        format!(
            "SWAP {} v{} -> v{} replicas={} drained={}",
            self.model, self.old_version, self.new_version, self.replicas, self.drained_requests
        )
    }
}

/// How long a swap waits for transient `Arc` clones of the old entry
/// (held briefly by racing submissions) to drop before giving up.
const SWAP_DRAIN_TIMEOUT: Duration = Duration::from_secs(60);

/// A submission that catches a pool mid-swap retries against the re-read
/// map this many times before surfacing the error.
const SUBMIT_RETRIES: usize = 4;

/// The model registry: named, versioned replica sets behind one
/// [`SubmitTarget`] face.
pub struct Registry {
    models: RwLock<HashMap<String, Arc<ModelEntry>>>,
    default_model: String,
    /// Template config for per-model pools (batching knobs, backend,
    /// policy); `workers`/`queue_depth` act as pool-wide budgets that
    /// shares carve up.
    base: ServerConfig,
    total_workers: usize,
    /// Shared across every model's pool — ids stay globally unique.
    next_id: Arc<AtomicU64>,
    /// One ring for all models; traces carry a `model=` tag.
    trace: Arc<TraceRing>,
    metrics: MetricsRegistry,
    /// Serializes swaps (loads/swaps are rare admin operations).
    swap_lock: Mutex<()>,
    unknown_model: AtomicU64,
    swaps: AtomicU64,
}

impl Registry {
    /// Start a registry from `config.models` (`name=path.rpz[@share]`
    /// entries): every model is loaded and warmed before this returns.
    pub fn start(config: &ServerConfig) -> Result<Registry> {
        config.validate()?;
        let specs = config.model_specs()?;
        if specs.is_empty() {
            bail!("registry needs at least one model (config key `models`)");
        }
        let default_model = if config.default_model.is_empty() {
            specs[0].name.clone()
        } else {
            config.default_model.clone()
        };
        let registry = Registry {
            models: RwLock::new(HashMap::new()),
            default_model,
            base: config.clone(),
            // every model gets at least one replica even when the worker
            // budget is smaller than the model count
            total_workers: config.workers,
            next_id: Arc::new(AtomicU64::new(0)),
            trace: Arc::new(TraceRing::new(TRACE_RING_CAPACITY, config.trace_sample)),
            metrics: MetricsRegistry::new(),
            swap_lock: Mutex::new(()),
            unknown_model: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
        };
        let total_share: f64 = specs.iter().map(|s| s.share).sum();
        for spec in &specs {
            let entry = registry.build_entry(spec, spec.share / total_share, 1)?;
            registry.models.write().unwrap().insert(spec.name.clone(), entry);
        }
        Ok(registry)
    }

    /// Replica count for a normalized share: the model's slice of the
    /// worker budget, never below one warm replica.
    fn replicas_for(&self, share_frac: f64) -> usize {
        let slice = (share_frac * self.total_workers as f64).round() as usize;
        slice.clamp(1, self.total_workers.max(1))
    }

    /// Admission quota for a normalized share: the model's slice of the
    /// pool-wide queue depth, never below one batch.
    fn quota_for(&self, share_frac: f64) -> usize {
        let slice = (share_frac * self.base.queue_depth as f64).round() as usize;
        slice.max(self.base.batch)
    }

    /// Load + warm one model version into a publishable entry.  Runs
    /// entirely off the serving path: plan compilation happens here, on
    /// the caller thread, before anything touches the model map.
    fn build_entry(
        &self,
        spec: &ModelSpec,
        share_frac: f64,
        version: u64,
    ) -> Result<Arc<ModelEntry>> {
        let replicas = self.replicas_for(share_frac);
        let factory = EngineFactory::for_artifact(
            Path::new(&spec.path),
            &self.base.backend,
            self.base.batch,
            PathBuf::from(&self.base.artifacts_dir),
            1,
        )
        .with_context(|| format!("model {:?}: load {}", spec.name, spec.path))?;
        let cfg = ServerConfig {
            workers: replicas,
            queue_depth: self.quota_for(share_frac),
            artifact: String::new(),
            listen: String::new(),
            models: String::new(),
            default_model: String::new(),
            ..self.base.clone()
        };
        let pool = ServePool::start_shared(&cfg, factory, self.next_id.clone(), self.trace.clone())
            .with_context(|| format!("model {:?}: start replica set", spec.name))?;
        Ok(Arc::new(ModelEntry {
            name: spec.name.clone(),
            version,
            path: spec.path.clone(),
            share: spec.share,
            replicas,
            pool,
            requests: AtomicU64::new(0),
        }))
    }

    /// Register a new model at runtime (unit traffic share).  Fails if
    /// the name is taken — replacing a live model is [`Registry::swap`].
    pub fn load(&self, name: &str, path: &str) -> Result<()> {
        self.load_with_share(name, path, 1.0)
    }

    pub fn load_with_share(&self, name: &str, path: &str, share: f64) -> Result<()> {
        if !(share.is_finite() && share > 0.0) {
            bail!("model {name:?}: share must be finite and > 0, got {share}");
        }
        let _admin = self.swap_lock.lock().unwrap();
        let share_frac = {
            let models = self.models.read().unwrap();
            if models.contains_key(name) {
                bail!("model {name:?} already loaded (use swap to replace it)");
            }
            let total: f64 = models.values().map(|e| e.share).sum::<f64>() + share;
            share / total
        };
        let spec = ModelSpec {
            name: name.to_string(),
            path: path.to_string(),
            share,
        };
        let entry = self.build_entry(&spec, share_frac, 1)?;
        // the admin lock guarantees nobody inserted the name concurrently
        self.models.write().unwrap().insert(name.to_string(), entry);
        Ok(())
    }

    /// Zero-downtime hot swap: warm `path` as the next version of
    /// `name`, atomically flip the registry entry, then drain the old
    /// replica set.  In-flight and queued requests complete on the old
    /// version; submissions after the flip land on the new one; the call
    /// returns only after the old pool has fully drained and joined.
    pub fn swap(&self, name: &str, path: &str) -> Result<SwapReport> {
        let _admin = self.swap_lock.lock().unwrap();
        let (share, share_frac, old_version) = {
            let models = self.models.read().unwrap();
            let entry = models
                .get(name)
                .with_context(|| format!("unknown model {name:?}"))?;
            let total: f64 = models.values().map(|e| e.share).sum();
            (entry.share, entry.share / total, entry.version)
        };
        // 1. warm the new version off the serving path
        let spec = ModelSpec {
            name: name.to_string(),
            path: path.to_string(),
            share,
        };
        let fresh = self.build_entry(&spec, share_frac, old_version + 1)?;
        let replicas = fresh.replicas;
        // 2. atomic flip: everything submitted from here on serves v+1
        let old = self
            .models
            .write()
            .unwrap()
            .insert(name.to_string(), fresh)
            .expect("entry existed under the admin lock");
        // 3. drain: wait out transient Arc clones held by racing
        //    submissions (they drop within one enqueue call), then shut
        //    the old pool down — shard shutdown executes the backlog, so
        //    every accepted request still gets its reply
        let deadline = Instant::now() + SWAP_DRAIN_TIMEOUT;
        let mut old = old;
        let entry = loop {
            match Arc::try_unwrap(old) {
                Ok(entry) => break entry,
                Err(arc) => {
                    if Instant::now() >= deadline {
                        bail!("swap {name:?}: old replica set still referenced after drain wait");
                    }
                    old = arc;
                    thread::sleep(Duration::from_micros(200));
                }
            }
        };
        let drained_requests = entry.requests.load(Ordering::Relaxed);
        entry
            .pool
            .shutdown()
            .with_context(|| format!("swap {name:?}: drain old replica set"))?;
        self.swaps.fetch_add(1, Ordering::Relaxed);
        Ok(SwapReport {
            model: name.to_string(),
            old_version,
            new_version: old_version + 1,
            replicas,
            drained_requests,
        })
    }

    fn entry(&self, model: Option<&str>) -> Result<Arc<ModelEntry>> {
        let name = model.unwrap_or(&self.default_model);
        let models = self.models.read().unwrap();
        match models.get(name) {
            Some(entry) => Ok(entry.clone()),
            None => {
                self.unknown_model.fetch_add(1, Ordering::Relaxed);
                let mut known: Vec<&str> = models.keys().map(String::as_str).collect();
                known.sort_unstable();
                bail!("unknown model {name:?} (loaded: {})", known.join(", "))
            }
        }
    }

    /// The routed submission primitive: resolve `model` (`None` = the
    /// default model), enqueue on its pool, and tag the trace.  Retries
    /// when the resolved pool is mid-swap — the re-read map already
    /// holds the new version, so the race costs a retry, not an error.
    pub fn submit_to(
        &self,
        model: Option<&str>,
        input: Vec<i32>,
        priority: Priority,
        deadline: Option<Instant>,
        reply: mpsc::Sender<Reply>,
    ) -> Result<RequestId> {
        let mut attempt = 0;
        loop {
            let entry = self.entry(model)?;
            match entry
                .pool
                .enqueue(input.clone(), priority, deadline, reply.clone())
            {
                Ok(id) => {
                    entry.requests.fetch_add(1, Ordering::Relaxed);
                    self.trace.set_model(id, &entry.name);
                    return Ok(id);
                }
                Err(err) => {
                    attempt += 1;
                    let racing_swap = err.to_string().contains("shutting down");
                    if !(racing_swap && attempt < SUBMIT_RETRIES) {
                        return Err(err.context(format!("model {:?}", entry.name)));
                    }
                }
            }
        }
    }

    /// The `MODELS` wire lines, sorted by name: one
    /// `MODEL name=... version=... replicas=... share=... requests=...
    /// default=0|1` per registered model.
    pub fn model_lines(&self) -> Vec<String> {
        let models = self.models.read().unwrap();
        let mut entries: Vec<&Arc<ModelEntry>> = models.values().collect();
        entries.sort_by(|a, b| a.name.cmp(&b.name));
        entries
            .iter()
            .map(|e| {
                format!(
                    "MODEL name={} version={} replicas={} share={:.2} requests={} default={}",
                    e.name,
                    e.version,
                    e.replicas,
                    e.share,
                    e.requests(),
                    u8::from(e.name == self.default_model),
                )
            })
            .collect()
    }

    pub fn default_model(&self) -> &str {
        &self.default_model
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total replicas across all models (worker threads running).
    pub fn replicas_total(&self) -> usize {
        self.models.read().unwrap().values().map(|e| e.replicas).sum()
    }

    /// Completed hot swaps.
    pub fn swaps_total(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Submissions bounced for naming a model that is not loaded.
    pub fn unknown_model_total(&self) -> u64 {
        self.unknown_model.load(Ordering::Relaxed)
    }

    /// Graceful shutdown: drain every model's replica set.
    pub fn shutdown(self) -> Result<()> {
        let mut models = self.models.into_inner().unwrap();
        let mut first_err = None;
        for (name, entry) in models.drain() {
            match Arc::try_unwrap(entry) {
                Ok(entry) => {
                    if let Err(e) = entry.pool.shutdown() {
                        first_err = first_err.or(Some(e.context(format!("model {name:?}"))));
                    }
                }
                // a clone outlived the registry (leaked handle): the
                // pool drains via Drop instead
                Err(_) => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Prometheus identifiers allow `[a-zA-Z0-9_:]`; model names are free
/// text on the wire, so map anything else to `_`.
fn metric_ident(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// The TCP frontend drives the registry exactly like a single pool:
/// `submit_with` routes to the default model, the `@<model>` wire forms
/// come in through [`SubmitTarget::submit_model`], and `STATS` merges
/// every model's shards into one report.
impl SubmitTarget for Registry {
    fn submit_with(
        &self,
        input: Vec<i32>,
        priority: Priority,
        deadline: Option<Instant>,
        reply: mpsc::Sender<Reply>,
    ) -> Result<RequestId> {
        self.submit_to(None, input, priority, deadline, reply)
    }

    fn submit_model(
        &self,
        model: Option<&str>,
        input: Vec<i32>,
        priority: Priority,
        deadline: Option<Instant>,
        reply: mpsc::Sender<Reply>,
    ) -> Result<RequestId> {
        self.submit_to(model, input, priority, deadline, reply)
    }

    fn stats(&self) -> StatsReport {
        let models = self.models.read().unwrap();
        let aggregate =
            ShardMetrics::merged(models.values().flat_map(|e| e.pool.shard_metrics()));
        StatsReport {
            requests: aggregate.requests,
            batches: aggregate.batches,
            rejected: models.values().map(|e| e.pool.rejected_total()).sum(),
            mean_latency_s: aggregate.mean_latency_s,
            p50_latency_s: aggregate.p50_latency_s,
            p95_latency_s: aggregate.p95_latency_s,
            p99_latency_s: aggregate.p99_latency_s,
            occupancy: aggregate.occupancy,
            promoted: aggregate.promoted,
            throughput: aggregate.throughput,
            throughput_10s: aggregate.throughput_10s,
            workers: models.values().map(|e| e.pool.workers()).sum(),
            shed: aggregate.shed,
            autoscale_spawns: models
                .values()
                .map(|e| e.pool.autoscale_counts().0)
                .sum(),
            autoscale_parks: models
                .values()
                .map(|e| e.pool.autoscale_counts().1)
                .sum(),
        }
    }

    fn traces(&self) -> Option<Arc<TraceRing>> {
        Some(self.trace.clone())
    }

    fn prometheus(&self) -> String {
        let report = self.stats();
        let r = &self.metrics;
        r.set_counter("zdnn_requests_total", report.requests);
        r.set_counter("zdnn_batches_total", report.batches);
        r.set_counter("zdnn_promoted_total", report.promoted);
        r.set_counter("zdnn_rejected_total", report.rejected);
        r.set_counter("zdnn_shed_total", report.shed);
        r.set_gauge("zdnn_occupancy", report.occupancy);
        r.set_gauge("zdnn_throughput", report.throughput);
        r.set_gauge("zdnn_throughput_10s", report.throughput_10s);
        r.set_gauge("zdnn_mean_latency_s", report.mean_latency_s);
        r.set_gauge("zdnn_p99_latency_s", report.p99_latency_s);
        r.set_gauge("zdnn_workers", report.workers as f64);
        r.set_gauge("zdnn_models", self.len() as f64);
        r.set_counter("zdnn_swaps_total", self.swaps_total());
        r.set_counter("zdnn_unknown_model_total", self.unknown_model_total());
        {
            let models = self.models.read().unwrap();
            for entry in models.values() {
                let ident = metric_ident(&entry.name);
                r.set_counter(
                    &format!("zdnn_model_{ident}_requests_total"),
                    entry.requests(),
                );
                r.set_gauge(&format!("zdnn_model_{ident}_version"), entry.version as f64);
                r.set_gauge(
                    &format!("zdnn_model_{ident}_replicas"),
                    entry.replicas as f64,
                );
                r.set_gauge(
                    &format!("zdnn_model_{ident}_in_flight"),
                    entry.pool.in_flight() as f64,
                );
            }
        }
        r.set_counter("zdnn_traces_recorded_total", self.trace.recorded());
        r.set_counter("zdnn_traces_evicted_total", self.trace.evicted());
        r.render_prometheus()
    }

    fn models(&self) -> Option<Vec<String>> {
        Some(self.model_lines())
    }

    fn swap_model(&self, name: &str, path: &str) -> Result<String> {
        self.swap(name, path).map(|report| report.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::random_qnet;
    use crate::compress::{save_artifact, CompressedModel};
    use crate::coordinator::request::SubmitOptions;
    use crate::nn::forward_q;
    use crate::nn::spec::quickstart;
    use crate::nn::QNetwork;
    use crate::sim::pruning::prune_qnetwork;
    use crate::tensor::MatI;
    use crate::util::rng::Xoshiro256;

    /// Write a quickstart-shaped `.rpz` artifact and return the exact
    /// network it decodes to (the served weights, golden for assertions).
    fn write_rpz(dir: &Path, file: &str, seed: u64) -> (PathBuf, QNetwork) {
        let net = prune_qnetwork(&random_qnet(&quickstart(), seed), 0.9);
        let model = CompressedModel::from_network(&net, 0.75, 0.02, 0.9, 0.89).unwrap();
        let served = model.to_qnetwork().unwrap();
        let path = dir.join(file);
        save_artifact(&path, &model).unwrap();
        (path, served)
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "zdnn-registry-{tag}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn rand_sample(seed: u64) -> Vec<i32> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..64)
            .map(|_| crate::fixedpoint::quantize(rng.uniform(-1.0, 1.0)))
            .collect()
    }

    fn golden(net: &QNetwork, input: &[i32]) -> Vec<i32> {
        forward_q(net, &MatI::from_vec(1, 64, input.to_vec()))
            .unwrap()
            .row(0)
            .to_vec()
    }

    fn registry_config(models: String, workers: usize) -> ServerConfig {
        ServerConfig {
            models,
            workers,
            batch: 4,
            batch_deadline_us: 300,
            ..Default::default()
        }
    }

    #[test]
    fn routes_by_model_name_with_default_fallback() {
        let dir = temp_dir("route");
        let (pa, net_a) = write_rpz(&dir, "a.rpz", 0xA);
        let (pb, net_b) = write_rpz(&dir, "b.rpz", 0xB);
        let models = format!("alpha={}@3,beta={}@1", pa.display(), pb.display());
        let registry = Registry::start(&registry_config(models, 4)).unwrap();
        assert_eq!(registry.default_model(), "alpha");
        assert_eq!(registry.len(), 2);

        for seed in 0..6u64 {
            let input = rand_sample(seed);
            // explicit routing to each model
            let (tx, rx) = mpsc::channel();
            let opts = SubmitOptions::interactive();
            let id = registry
                .submit_to(Some("beta"), input.clone(), Priority::Interactive, None, tx)
                .unwrap();
            let resp = crate::coordinator::request::Ticket::new(id, &opts, rx)
                .wait_timeout(Duration::from_secs(5))
                .unwrap();
            assert_eq!(resp.output, golden(&net_b, &input), "beta seed {seed}");
            // default routing through the plain SubmitTarget surface
            let resp = registry
                .infer_prioritized(input.clone(), Priority::Bulk)
                .unwrap();
            assert_eq!(resp.output, golden(&net_a, &input), "alpha seed {seed}");
        }

        let err = registry
            .submit_to(
                Some("nope"),
                rand_sample(0),
                Priority::Bulk,
                None,
                mpsc::channel().0,
            )
            .unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
        assert_eq!(registry.unknown_model_total(), 1);

        let lines = registry.model_lines();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("MODEL name=alpha version=1 replicas=3"), "{}", lines[0]);
        assert!(lines[0].ends_with("default=1"), "{}", lines[0]);
        assert!(lines[1].contains("name=beta"), "{}", lines[1]);
        assert!(lines[1].contains("replicas=1"), "{}", lines[1]);
        assert!(lines[1].ends_with("default=0"), "{}", lines[1]);
        registry.shutdown().unwrap();
    }

    #[test]
    fn shares_size_replicas_and_admission_quotas() {
        let dir = temp_dir("shares");
        let (pa, _) = write_rpz(&dir, "big.rpz", 1);
        let (pb, _) = write_rpz(&dir, "small.rpz", 2);
        let models = format!("big={}@3,small={}@1", pa.display(), pb.display());
        let cfg = ServerConfig {
            queue_depth: 40,
            ..registry_config(models, 4)
        };
        let registry = Registry::start(&cfg).unwrap();
        // 3/4 of 4 workers = 3 replicas; 1/4 = 1 replica
        let lines = registry.model_lines();
        assert!(lines[0].contains("name=big") && lines[0].contains("replicas=3"), "{}", lines[0]);
        assert!(lines[1].contains("name=small") && lines[1].contains("replicas=1"), "{}", lines[1]);
        assert_eq!(registry.replicas_total(), 4);
        // quotas: big = 30 slots, small = 10 — the arithmetic is private,
        // so assert the observable part: sizing helpers round and floor
        assert_eq!(registry.replicas_for(0.75), 3);
        assert_eq!(registry.replicas_for(0.01), 1, "never below one replica");
        assert_eq!(registry.quota_for(0.75), 30);
        assert_eq!(registry.quota_for(0.25), 10);
        assert_eq!(registry.quota_for(0.0), cfg.batch, "never below one batch");
        registry.shutdown().unwrap();
    }

    #[test]
    fn swap_bumps_version_and_reroutes_new_submissions() {
        let dir = temp_dir("swapv");
        let (p1, net_v1) = write_rpz(&dir, "v1.rpz", 0x11);
        let (p2, net_v2) = write_rpz(&dir, "v2.rpz", 0x22);
        let models = format!("m={}", p1.display());
        let registry = Registry::start(&registry_config(models, 2)).unwrap();

        let input = rand_sample(7);
        let resp = registry.infer_prioritized(input.clone(), Priority::Interactive).unwrap();
        assert_eq!(resp.output, golden(&net_v1, &input));

        let report = registry.swap("m", &p2.display().to_string()).unwrap();
        assert_eq!(report.old_version, 1);
        assert_eq!(report.new_version, 2);
        assert_eq!(report.drained_requests, 1);
        assert!(report.render().starts_with("SWAP m v1 -> v2"), "{}", report.render());
        assert_eq!(registry.swaps_total(), 1);

        let resp = registry.infer_prioritized(input.clone(), Priority::Interactive).unwrap();
        assert_eq!(resp.output, golden(&net_v2, &input), "post-swap serves v2");
        assert!(registry.model_lines()[0].contains("version=2"));

        assert!(registry.swap("ghost", &p2.display().to_string()).is_err());
        let err = registry.swap("m", "/nonexistent/model.rpz").unwrap_err();
        assert!(format!("{err:#}").contains("m"), "{err:#}");
        // a failed swap leaves the live version serving
        let resp = registry.infer_prioritized(input.clone(), Priority::Bulk).unwrap();
        assert_eq!(resp.output, golden(&net_v2, &input));
        registry.shutdown().unwrap();
    }

    /// The exactly-once property under concurrency: submitters hammer the
    /// model while a swap flips it.  Every accepted request gets exactly
    /// one reply (tickets enforce one-shot consumption), every reply
    /// matches one of the two versions' goldens, and everything submitted
    /// after the swap returns matches v2 only.
    #[test]
    fn concurrent_submits_survive_hot_swap_exactly_once() {
        let dir = temp_dir("swaprace");
        let (p1, net_v1) = write_rpz(&dir, "r1.rpz", 0x31);
        let (p2, net_v2) = write_rpz(&dir, "r2.rpz", 0x32);
        let models = format!("m={}", p1.display());
        let registry = Arc::new(Registry::start(&registry_config(models, 3)).unwrap());

        let submitters: Vec<_> = (0..3u64)
            .map(|t| {
                let reg = registry.clone();
                thread::spawn(move || {
                    let mut pairs = Vec::new();
                    for i in 0..40u64 {
                        let input = rand_sample(t * 1000 + i);
                        let priority = if i % 3 == 0 {
                            Priority::Interactive
                        } else {
                            Priority::Bulk
                        };
                        let ticket = reg
                            .submit(input.clone(), SubmitOptions::with_priority(priority))
                            .expect("submit never bounces during swap");
                        pairs.push((input, ticket));
                        if i % 8 == 0 {
                            thread::sleep(Duration::from_micros(300));
                        }
                    }
                    pairs
                })
            })
            .collect();
        // let the submitters get going, then flip mid-stream
        thread::sleep(Duration::from_millis(2));
        let report = registry.swap("m", &p2.display().to_string()).unwrap();
        assert_eq!(report.new_version, 2);

        let mut v1_replies = 0usize;
        let mut v2_replies = 0usize;
        for handle in submitters {
            for (input, mut ticket) in handle.join().unwrap() {
                let resp = ticket
                    .wait_timeout(Duration::from_secs(10))
                    .expect("every accepted request gets exactly one reply");
                let out = resp.output;
                if out == golden(&net_v1, &input) {
                    v1_replies += 1;
                } else if out == golden(&net_v2, &input) {
                    v2_replies += 1;
                } else {
                    panic!("reply matches neither version's golden");
                }
            }
        }
        assert_eq!(v1_replies + v2_replies, 120, "nothing lost, nothing duplicated");
        // post-drain submissions serve v2 exclusively
        for seed in 500..510u64 {
            let input = rand_sample(seed);
            let resp = registry.infer_prioritized(input.clone(), Priority::Interactive).unwrap();
            assert_eq!(resp.output, golden(&net_v2, &input));
        }
        Arc::try_unwrap(registry)
            .unwrap_or_else(|_| panic!("registry still referenced"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn load_registers_new_models_and_rejects_duplicates() {
        let dir = temp_dir("load");
        let (pa, _) = write_rpz(&dir, "first.rpz", 5);
        let (pb, net_b) = write_rpz(&dir, "second.rpz", 6);
        let models = format!("first={}", pa.display());
        let registry = Registry::start(&registry_config(models, 2)).unwrap();
        registry.load("second", &pb.display().to_string()).unwrap();
        assert_eq!(registry.len(), 2);
        let input = rand_sample(9);
        let (tx, rx) = mpsc::channel();
        let opts = SubmitOptions::interactive();
        let id = registry
            .submit_to(Some("second"), input.clone(), Priority::Interactive, None, tx)
            .unwrap();
        let resp = crate::coordinator::request::Ticket::new(id, &opts, rx)
            .wait_timeout(Duration::from_secs(5))
            .unwrap();
        assert_eq!(resp.output, golden(&net_b, &input));
        assert!(registry.load("second", &pb.display().to_string()).is_err());
        registry.shutdown().unwrap();
    }

    #[test]
    fn stats_merge_across_models_and_prometheus_tags_per_model() {
        let dir = temp_dir("stats");
        let (pa, _) = write_rpz(&dir, "sa.rpz", 21);
        let (pb, _) = write_rpz(&dir, "sb.rpz", 22);
        let models = format!("sa={},sb={}", pa.display(), pb.display());
        let registry = Registry::start(&registry_config(models, 2)).unwrap();
        for seed in 0..4u64 {
            registry.infer_prioritized(rand_sample(seed), Priority::Bulk).unwrap();
            let (tx, rx) = mpsc::channel();
            let opts = SubmitOptions::bulk();
            let id = registry
                .submit_to(Some("sb"), rand_sample(seed), Priority::Bulk, None, tx)
                .unwrap();
            crate::coordinator::request::Ticket::new(id, &opts, rx)
                .wait_timeout(Duration::from_secs(5))
                .unwrap();
        }
        let report = registry.stats();
        assert_eq!(report.requests, 8, "merged across both models");
        assert_eq!(report.workers, 2);
        let prom = registry.prometheus();
        assert!(prom.contains("zdnn_model_sa_requests_total 4"), "{prom}");
        assert!(prom.contains("zdnn_model_sb_requests_total 4"), "{prom}");
        assert!(prom.contains("zdnn_models 2"), "{prom}");
        assert!(prom.contains("zdnn_swaps_total 0"), "{prom}");
        assert!(prom.ends_with("# EOF\n"), "{prom}");
        // traces carry the model tag through the shared ring
        let traces = registry.traces().unwrap().last(8);
        assert!(!traces.is_empty());
        assert!(traces.iter().all(|t| {
            matches!(t.model.as_deref(), Some("sa") | Some("sb"))
        }));
        registry.shutdown().unwrap();
    }

    #[test]
    fn metric_ident_sanitizes_free_text_names() {
        assert_eq!(metric_ident("mnist-4.v2"), "mnist_4_v2");
        assert_eq!(metric_ident("plain"), "plain");
    }
}
