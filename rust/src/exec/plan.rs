//! [`ExecPlan`]: a network compiled to per-layer kernels plus reusable
//! activation buffers.  See the module docs ([`crate::exec`]) for the
//! kernel-selection policy.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::compress::{CompressedModel, LayerBlob};
use crate::nn::forward::QNetwork;
use crate::obs::profile::PlanProfile;
use crate::nn::spec::{Activation, NetworkSpec};
use crate::sparse;
use crate::tensor::{
    column_nonzero_mask, gemm_f32, gemm_i32, gemm_i32_parallel, spmm_codebook_i32_opt,
    spmm_codebook_i32_opt_parallel, spmm_i32_opt, spmm_i32_opt_parallel, CsrCodebookMatI,
    CsrMatI, MatF, MatI,
};
use crate::util::threadpool::ThreadPool;

/// Default minimum per-layer pruning factor at which the compiler selects
/// the sparse kernel.  Conservative: the CSR kernel's per-non-zero
/// indexing costs roughly 2–3 dense MACs, so sparse only wins once ≥ ~3/4
/// of the weights are gone (the paper's evaluation networks prune to
/// 0.72–0.94, all on the winning side for their large layers).
pub const DEFAULT_SPARSE_THRESHOLD: f64 = 0.75;

/// Minimum zero-column fraction of a post-ReLU activation batch at which
/// the sparse kernels engage the column mask.  Below this the per-entry
/// mask test costs more than the skipped MACs; the mask build itself is
/// O(batch × width), noise next to the SpMM it guards.
pub const ACT_SKIP_MIN_ZERO_FRAC: f64 = 0.25;

/// Plan-compilation knobs.
#[derive(Debug, Clone)]
pub struct PlanOptions {
    /// Minimum measured per-layer pruning factor (zero-weight fraction in
    /// [0, 1]) required to select `SparseQ`.  `0.0` forces sparse
    /// everywhere; any value > 1.0 (e.g. `f64::INFINITY`) forces dense.
    pub sparse_threshold: f64,
    /// Worker threads for the row-partitioned parallel kernels; ≤ 1 keeps
    /// every kernel serial.
    pub threads: usize,
    /// Sort sparse rows by descending non-zero count at compile time
    /// (spada-sim's `sort_by_row_length`); outputs are un-permuted through
    /// a stored index, so results are bit-identical either way.
    pub reorder_rows: bool,
    /// Skip whole-zero activation columns after ReLU layers (EIE's
    /// dynamic activation sparsity).  Engaged per batch only when the
    /// zero-column fraction reaches [`ACT_SKIP_MIN_ZERO_FRAC`];
    /// bit-identical either way (a skipped column contributes exactly 0).
    pub activation_skip: bool,
    /// Record per-layer kernel timing into the plan's
    /// [`PlanProfile`](crate::obs::profile::PlanProfile) (off by default:
    /// disabled profiling costs the per-layer loop one branch).
    pub profile: bool,
}

impl Default for PlanOptions {
    fn default() -> Self {
        Self {
            sparse_threshold: DEFAULT_SPARSE_THRESHOLD,
            threads: 1,
            reorder_rows: false,
            activation_skip: true,
            profile: false,
        }
    }
}

impl PlanOptions {
    /// Never select the sparse kernel (the golden dense path).
    pub fn dense_only() -> Self {
        Self {
            sparse_threshold: f64::INFINITY,
            ..Self::default()
        }
    }

    /// Select the sparse kernel for every layer (the `native-sparse`
    /// backend; bit-identical, only the time axis moves).
    pub fn sparse_always() -> Self {
        Self {
            sparse_threshold: 0.0,
            ..Self::default()
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_reorder_rows(mut self, on: bool) -> Self {
        self.reorder_rows = on;
        self
    }

    pub fn with_activation_skip(mut self, on: bool) -> Self {
        self.activation_skip = on;
        self
    }

    pub fn with_profile(mut self, on: bool) -> Self {
        self.profile = on;
        self
    }
}

/// Which kernel a layer compiled to (introspection for tests, benches, and
/// reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    DenseQ,
    SparseQ,
    /// CSR with EIE weight sharing: 4-bit codes + 16-entry LUT.
    CodebookQ,
    DenseF32,
}

/// A compiled sparse layer: the CSR stream plus the output-column
/// un-permutation when the rows were reordered by nnz.
struct SparseData {
    csr: CsrMatI,
    out_col: Option<Vec<u32>>,
}

impl SparseData {
    fn new(csr: CsrMatI, reorder: bool) -> Self {
        if reorder {
            let (csr, out_col) = csr.reorder_by_nnz();
            Self {
                csr,
                out_col: Some(out_col),
            }
        } else {
            Self {
                csr,
                out_col: None,
            }
        }
    }
}

/// A compiled codebook layer (see [`SparseData`]).
struct CodebookData {
    mat: CsrCodebookMatI,
    out_col: Option<Vec<u32>>,
}

impl CodebookData {
    fn new(mat: CsrCodebookMatI, reorder: bool) -> Self {
        if reorder {
            let (mat, out_col) = mat.reorder_by_nnz();
            Self {
                mat,
                out_col: Some(out_col),
            }
        } else {
            Self {
                mat,
                out_col: None,
            }
        }
    }
}

/// Kernels hold their weight storage behind `Arc` so sharded serving can
/// replicate a compiled plan per worker ([`ExecPlan::clone_shared`])
/// without duplicating megabytes of weights: clones share the read-only
/// dense/CSR storage and own only their activation buffers.
enum Kernel {
    /// Register-blocked wrapping-i32 GEMM on the dense Q7.8 weights.
    DenseQ(Arc<MatI>),
    /// CSR sparse × dense wrapping GEMM derived from the §5.6 tuple stream.
    SparseQ(Arc<SparseData>),
    /// CSR with codebook-shared 4-bit values (EIE weight sharing).
    CodebookQ(Arc<CodebookData>),
    /// f32 GEMM (software-baseline path).
    DenseF32(Arc<MatF>),
}

impl Clone for Kernel {
    /// Cheap: clones the `Arc` handle, not the weight storage.
    fn clone(&self) -> Self {
        match self {
            Kernel::DenseQ(w) => Kernel::DenseQ(Arc::clone(w)),
            Kernel::SparseQ(w) => Kernel::SparseQ(Arc::clone(w)),
            Kernel::CodebookQ(w) => Kernel::CodebookQ(Arc::clone(w)),
            Kernel::DenseF32(w) => Kernel::DenseF32(Arc::clone(w)),
        }
    }
}

impl Kernel {
    fn kind(&self) -> KernelKind {
        match self {
            Kernel::DenseQ(_) => KernelKind::DenseQ,
            Kernel::SparseQ(_) => KernelKind::SparseQ,
            Kernel::CodebookQ(_) => KernelKind::CodebookQ,
            Kernel::DenseF32(_) => KernelKind::DenseF32,
        }
    }

    /// Sparse-family kernels can consume an activation-column mask.
    fn maskable(&self) -> bool {
        matches!(self, Kernel::SparseQ(_) | Kernel::CodebookQ(_))
    }

    /// Weights this kernel will actually visit for one batch — exact: the
    /// post-mask count for sparse kernels (an O(nnz) scan, profiling-only),
    /// full nnz unmasked, rows × cols for the dense families.
    fn effective_nnz(&self, mask: Option<&[bool]>) -> usize {
        match self {
            Kernel::DenseQ(w) => w.rows * w.cols,
            Kernel::DenseF32(w) => w.rows * w.cols,
            Kernel::SparseQ(d) => match mask {
                Some(m) => d.csr.col_idx().iter().filter(|&&c| m[c as usize]).count(),
                None => d.csr.nnz(),
            },
            Kernel::CodebookQ(d) => match mask {
                Some(m) => d.mat.col_idx().iter().filter(|&&c| m[c as usize]).count(),
                None => d.mat.nnz(),
            },
        }
    }
}

#[derive(Clone)]
struct LayerPlan {
    kernel: Kernel,
    act: Activation,
    out_dim: usize,
}

/// A network compiled for execution: per-layer kernels, double-buffered
/// activation storage, and an optional shared thread pool.
pub struct ExecPlan {
    spec: NetworkSpec,
    layers: Vec<LayerPlan>,
    pool: Option<Arc<ThreadPool>>,
    /// Ping-pong Q7.8 activation buffers (layer `j` writes `qbufs[j % 2]`).
    qbufs: [MatI; 2],
    /// Ping-pong f32 buffers (only used by `DenseF32` plans).
    fbufs: [MatF; 2],
    /// EIE activation-sparsity skipping enabled (see
    /// [`PlanOptions::activation_skip`]).
    act_skip: bool,
    /// Reusable column non-zero mask scratch for the skip path.
    colmask: Vec<bool>,
    /// Per-layer kernel profile, recording when compiled with
    /// [`PlanOptions::profile`] (the f32 baseline path is unprofiled).
    profile: Option<PlanProfile>,
}

impl ExecPlan {
    /// Compile a quantized network, choosing `SparseQ` per layer from its
    /// measured pruning factor (see [`crate::exec`] for the policy).
    pub fn compile_q(net: &QNetwork, opts: &PlanOptions) -> Result<Self> {
        let prune = net.prune_factors();
        let mut layers = Vec::with_capacity(net.weights.len());
        for ((w, &act), &q) in net
            .weights
            .iter()
            .zip(net.spec.activations.iter())
            .zip(prune.iter())
        {
            let kernel = if q >= opts.sparse_threshold {
                // encode through the paper's tuple stream so the serving
                // path exercises the same format the hardware consumes
                Kernel::SparseQ(Arc::new(SparseData::new(
                    sparse::encode_matrix(w)?.to_csr(),
                    opts.reorder_rows,
                )))
            } else {
                Kernel::DenseQ(Arc::new(w.clone()))
            };
            layers.push(LayerPlan {
                kernel,
                act,
                out_dim: w.rows,
            });
        }
        Self::new(net.spec.clone(), layers, opts)
    }

    /// Compile a compressed `.rpz` artifact
    /// ([`crate::compress::CompressedModel`]) with the default options at
    /// `threads` workers (activation skipping on, rows unreordered).
    pub fn compile_artifact(model: &CompressedModel, threads: usize) -> Result<Self> {
        Self::compile_artifact_with(model, &PlanOptions::default().with_threads(threads))
    }

    /// [`Self::compile_artifact`] with explicit [`PlanOptions`].  The
    /// kernel choice is the artifact's own — sparse blobs become
    /// `SparseQ`/`CodebookQ` kernels *directly* (no densify/re-encode on
    /// the load path) and dense blobs become `DenseQ`, so serving honours
    /// the calibrated `sparse_threshold` embedded at compression time;
    /// `opts.sparse_threshold` is ignored here.  `reorder_rows` and
    /// `activation_skip` apply to the compiled sparse kernels.
    pub fn compile_artifact_with(model: &CompressedModel, opts: &PlanOptions) -> Result<Self> {
        let shapes = model.spec.weight_shapes();
        ensure!(
            model.layers.len() == shapes.len(),
            "{}: {} layer blobs for {} weight matrices",
            model.spec.name,
            model.layers.len(),
            shapes.len()
        );
        let mut layers = Vec::with_capacity(model.layers.len());
        for ((blob, &act), &(o, i)) in model
            .layers
            .iter()
            .zip(model.spec.activations.iter())
            .zip(shapes.iter())
        {
            ensure!(
                blob.shape() == (o, i),
                "{}: blob shape {:?} != {:?}",
                model.spec.name,
                blob.shape(),
                (o, i)
            );
            let kernel = match blob {
                LayerBlob::Dense(w) => Kernel::DenseQ(Arc::new(w.clone())),
                LayerBlob::Csr(m) | LayerBlob::CsrDelta(m) => {
                    Kernel::SparseQ(Arc::new(SparseData::new(m.clone(), opts.reorder_rows)))
                }
                LayerBlob::Codebook(m) => {
                    Kernel::CodebookQ(Arc::new(CodebookData::new(m.clone(), opts.reorder_rows)))
                }
            };
            layers.push(LayerPlan {
                kernel,
                act,
                out_dim: o,
            });
        }
        Self::new(model.spec.clone(), layers, opts)
    }

    /// Compile the f32 software-baseline path.
    pub fn compile_f32(spec: &NetworkSpec, weights: &[MatF]) -> Result<Self> {
        let shapes = spec.weight_shapes();
        ensure!(
            weights.len() == shapes.len(),
            "{}: expected {} weight matrices, got {}",
            spec.name,
            shapes.len(),
            weights.len()
        );
        let mut layers = Vec::with_capacity(weights.len());
        for ((w, &act), &(o, i)) in weights.iter().zip(spec.activations.iter()).zip(shapes.iter())
        {
            ensure!(
                w.shape() == (o, i),
                "{}: weight shape {:?} != {:?}",
                spec.name,
                w.shape(),
                (o, i)
            );
            layers.push(LayerPlan {
                kernel: Kernel::DenseF32(Arc::new(w.clone())),
                act,
                out_dim: o,
            });
        }
        Self::new(spec.clone(), layers, &PlanOptions::default())
    }

    fn new(spec: NetworkSpec, layers: Vec<LayerPlan>, opts: &PlanOptions) -> Result<Self> {
        ensure!(!layers.is_empty(), "{}: network has no layers", spec.name);
        let profile = opts
            .profile
            .then(|| PlanProfile::new(layers.iter().map(|l| (l.kernel.kind(), l.out_dim))));
        Ok(Self {
            spec,
            layers,
            pool: (opts.threads > 1).then(|| Arc::new(ThreadPool::new(opts.threads))),
            qbufs: [MatI::zeros(0, 0), MatI::zeros(0, 0)],
            fbufs: [MatF::zeros(0, 0), MatF::zeros(0, 0)],
            act_skip: opts.activation_skip,
            colmask: Vec::new(),
            profile,
        })
    }

    pub fn spec(&self) -> &NetworkSpec {
        &self.spec
    }

    /// The kernel each layer compiled to, in layer order.
    pub fn kernels(&self) -> Vec<KernelKind> {
        self.layers.iter().map(|l| l.kernel.kind()).collect()
    }

    /// Share this plan's pool (e.g. with a sibling plan).  `None` when the
    /// plan was compiled single-threaded.
    pub fn pool(&self) -> Option<Arc<ThreadPool>> {
        self.pool.clone()
    }

    /// Replicate this plan for another worker: the clone shares the
    /// read-only kernel storage (dense weights / CSR streams, behind `Arc`)
    /// and the thread pool, but owns fresh activation buffers — so N
    /// serving shards cost N activation buffers, not N weight copies.
    pub fn clone_shared(&self) -> Self {
        Self {
            spec: self.spec.clone(),
            layers: self.layers.clone(),
            pool: self.pool.clone(),
            qbufs: [MatI::zeros(0, 0), MatI::zeros(0, 0)],
            fbufs: [MatF::zeros(0, 0), MatF::zeros(0, 0)],
            act_skip: self.act_skip,
            colmask: Vec::new(),
            // each clone records into its own profile (no cross-shard
            // synchronization); merge() folds them for a pool-wide view
            profile: self
                .profile
                .as_ref()
                .map(|_| PlanProfile::new(self.layers.iter().map(|l| (l.kernel.kind(), l.out_dim)))),
        }
    }

    /// The per-layer kernel profile accumulated so far (`None` unless the
    /// plan was compiled with [`PlanOptions::profile`]).
    pub fn profile(&self) -> Option<&PlanProfile> {
        self.profile.as_ref()
    }

    /// Execute one Q7.8 batch: `x` is (n × s_0), the result borrows the
    /// plan's activation buffers — clone it to keep it past the next run.
    pub fn run(&mut self, x: &MatI) -> Result<&MatI> {
        let pool = self.pool.clone();
        self.run_q(pool.as_deref(), x)
    }

    /// [`run`](Self::run) with a caller-borrowed pool (used by the
    /// `forward_q_parallel` wrapper); the plan's own pool is ignored.
    pub fn run_with(&mut self, pool: &ThreadPool, x: &MatI) -> Result<&MatI> {
        self.run_q(Some(pool), x)
    }

    fn run_q(&mut self, pool: Option<&ThreadPool>, x: &MatI) -> Result<&MatI> {
        ensure!(
            x.cols == self.spec.inputs(),
            "input width {} != {}",
            x.cols,
            self.spec.inputs()
        );
        let n = x.rows;
        // grow the ping-pong buffers to the widest layer once, up front —
        // the per-layer loop below only re-slices existing capacity
        let widest = self.layers.iter().map(|l| l.out_dim).max().unwrap_or(0);
        for b in self.qbufs.iter_mut() {
            b.data.reserve((n * widest).saturating_sub(b.data.len()));
        }
        let Self {
            layers,
            qbufs,
            colmask,
            act_skip,
            profile,
            ..
        } = self;
        let act_skip = *act_skip;
        for (j, layer) in layers.iter().enumerate() {
            let (lo, hi) = qbufs.split_at_mut(1);
            let (dst, prev) = if j % 2 == 0 {
                (&mut lo[0], &hi[0])
            } else {
                (&mut hi[0], &lo[0])
            };
            let src: &MatI = if j == 0 { x } else { prev };
            dst.rows = n;
            dst.cols = layer.out_dim;
            dst.data.resize(n * layer.out_dim, 0); // within capacity: no alloc
            // EIE activation sparsity: ReLU zeroes whole activation
            // columns; the sparse kernels can skip them entirely.  Only
            // worth the per-entry mask test when enough columns died.
            let mut cols_skipped = 0usize;
            let mask: Option<&[bool]> = if act_skip
                && j > 0
                && layer.kernel.maskable()
                && layers[j - 1].act == Activation::Relu
            {
                let nz = column_nonzero_mask(src, colmask);
                let zero_frac = (src.cols - nz) as f64 / src.cols.max(1) as f64;
                if zero_frac >= ACT_SKIP_MIN_ZERO_FRAC {
                    cols_skipped = src.cols - nz;
                }
                (zero_frac >= ACT_SKIP_MIN_ZERO_FRAC).then_some(colmask.as_slice())
            } else {
                None
            };
            let layer_t0 = profile.is_some().then(Instant::now);
            match &layer.kernel {
                Kernel::DenseQ(w) => match pool {
                    // row partitioning needs a few sample rows to win
                    Some(p) if n >= 4 => gemm_i32_parallel(p, src, w, dst),
                    _ => gemm_i32(src, w, dst),
                },
                Kernel::SparseQ(d) => {
                    let out_col = d.out_col.as_deref();
                    match pool {
                        // neuron partitioning parallelizes even batch 1,
                        // but needs enough rows to amortize the fork
                        Some(p) if d.csr.rows() >= 64 => {
                            spmm_i32_opt_parallel(p, src, &d.csr, dst, out_col, mask)
                        }
                        _ => spmm_i32_opt(src, &d.csr, dst, out_col, mask),
                    }
                }
                Kernel::CodebookQ(d) => {
                    let out_col = d.out_col.as_deref();
                    match pool {
                        Some(p) if d.mat.rows() >= 64 => {
                            spmm_codebook_i32_opt_parallel(p, src, &d.mat, dst, out_col, mask)
                        }
                        _ => spmm_codebook_i32_opt(src, &d.mat, dst, out_col, mask),
                    }
                }
                Kernel::DenseF32(_) => {
                    anyhow::bail!("{}: plan was compiled for f32; use run_f32", self.spec.name)
                }
            }
            for v in dst.data.iter_mut() {
                *v = layer.act.apply_acc(*v);
            }
            if let Some(p) = profile.as_mut() {
                let wall_ns = layer_t0.expect("set when profiling").elapsed().as_nanos() as u64;
                let eff_nnz = layer.kernel.effective_nnz(mask);
                p.record(j, wall_ns, n, mask.is_some(), cols_skipped, src.cols, eff_nnz);
            }
        }
        Ok(&self.qbufs[(self.layers.len() - 1) % 2])
    }

    /// Execute one f32 batch (software-baseline plans).
    ///
    /// Mirrors [`run_q`](Self::run_q)'s ping-pong machinery over `fbufs`;
    /// any change to the buffer-sizing or parity logic there must be made
    /// here too (kept as two concrete copies rather than one generic
    /// helper — the borrow gymnastics are the subtlest code in the file).
    /// The per-layer profiler is deliberately `run_q`-only: this path is
    /// the software baseline, not a serving path.
    pub fn run_f32(&mut self, x: &MatF) -> Result<&MatF> {
        ensure!(
            x.cols == self.spec.inputs(),
            "input width {} != {}",
            x.cols,
            self.spec.inputs()
        );
        let n = x.rows;
        let widest = self.layers.iter().map(|l| l.out_dim).max().unwrap_or(0);
        for b in self.fbufs.iter_mut() {
            b.data.reserve((n * widest).saturating_sub(b.data.len()));
        }
        let Self { layers, fbufs, .. } = self;
        for (j, layer) in layers.iter().enumerate() {
            let (lo, hi) = fbufs.split_at_mut(1);
            let (dst, prev) = if j % 2 == 0 {
                (&mut lo[0], &hi[0])
            } else {
                (&mut hi[0], &lo[0])
            };
            let src: &MatF = if j == 0 { x } else { prev };
            dst.rows = n;
            dst.cols = layer.out_dim;
            dst.data.resize(n * layer.out_dim, 0.0);
            match &layer.kernel {
                Kernel::DenseF32(w) => gemm_f32(src, w, dst),
                _ => anyhow::bail!("{}: plan was compiled for Q7.8; use run", self.spec.name),
            }
            for v in dst.data.iter_mut() {
                *v = layer.act.apply_f32(*v);
            }
        }
        Ok(&self.fbufs[(self.layers.len() - 1) % 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::quantize_matrix;
    use crate::nn::spec::quickstart;
    use crate::sim::pruning::prune_qnetwork;
    use crate::tensor::gemm_i32_naive;
    use crate::util::prop::prop_check;
    use crate::util::rng::Xoshiro256;

    /// Independent oracle: the pre-plan forward_q body (naive GEMM +
    /// activation), deliberately *not* routed through any plan.
    fn reference_forward_q(net: &QNetwork, x: &MatI) -> MatI {
        let mut a = x.clone();
        for (w, act) in net.weights.iter().zip(net.spec.activations.iter()) {
            let mut z = MatI::zeros(a.rows, w.rows);
            gemm_i32_naive(&a, w, &mut z);
            for v in z.data.iter_mut() {
                *v = act.apply_acc(*v);
            }
            a = z;
        }
        a
    }

    fn rand_qnet(spec: NetworkSpec, seed: u64) -> QNetwork {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let ws = spec
            .weight_shapes()
            .iter()
            .map(|&(o, i)| {
                quantize_matrix(&MatF::from_vec(
                    o,
                    i,
                    (0..o * i).map(|_| rng.normal_scaled(0.0, 0.1) as f32).collect(),
                ))
            })
            .collect();
        QNetwork::new(spec, ws).unwrap()
    }

    fn rand_x(n: usize, cols: usize, seed: u64) -> MatI {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        quantize_matrix(&MatF::from_vec(
            n,
            cols,
            (0..n * cols).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        ))
    }

    #[test]
    fn policy_picks_sparse_above_threshold() {
        let net = rand_qnet(quickstart(), 1);
        let dense = ExecPlan::compile_q(&net, &PlanOptions::default()).unwrap();
        assert_eq!(dense.kernels(), vec![KernelKind::DenseQ; 2]);
        let pruned = prune_qnetwork(&net, 0.9);
        let plan = ExecPlan::compile_q(&pruned, &PlanOptions::default()).unwrap();
        assert_eq!(plan.kernels(), vec![KernelKind::SparseQ; 2]);
        let forced = ExecPlan::compile_q(&pruned, &PlanOptions::dense_only()).unwrap();
        assert_eq!(forced.kernels(), vec![KernelKind::DenseQ; 2]);
    }

    #[test]
    fn sparse_plan_bit_identical_to_reference() {
        for q in [0.0, 0.5, 0.9, 0.99] {
            let net = prune_qnetwork(&rand_qnet(quickstart(), 2), q);
            let x = rand_x(5, 64, 3);
            let want = reference_forward_q(&net, &x);
            for opts in [
                PlanOptions::default(),
                PlanOptions::sparse_always(),
                PlanOptions::dense_only(),
                PlanOptions::sparse_always().with_threads(3),
                PlanOptions::dense_only().with_threads(3),
            ] {
                let mut plan = ExecPlan::compile_q(&net, &opts).unwrap();
                assert_eq!(plan.run(&x).unwrap().data, want.data, "q={q} {opts:?}");
            }
        }
    }

    #[test]
    fn artifact_plan_bit_identical_to_network_plan() {
        // the .rpz load path (CSR blobs -> SparseQ kernels directly) must
        // agree bit-for-bit with compiling the reconstructed network at
        // the artifact's embedded threshold
        let net = prune_qnetwork(&rand_qnet(quickstart(), 9), 0.85);
        let model =
            crate::compress::CompressedModel::from_network(&net, 0.75, 0.0, 1.0, 1.0).unwrap();
        let mut from_art = ExecPlan::compile_artifact(&model, 1).unwrap();
        assert_eq!(from_art.kernels(), vec![KernelKind::SparseQ; 2]);
        let opts = PlanOptions {
            sparse_threshold: 0.75,
            ..PlanOptions::default()
        };
        let mut from_net = ExecPlan::compile_q(&net, &opts).unwrap();
        let x = rand_x(5, 64, 10);
        assert_eq!(
            from_art.run(&x).unwrap().data,
            from_net.run(&x).unwrap().data
        );
    }

    #[test]
    fn codebook_artifact_compiles_codebook_kernels_bit_identical() {
        // weight-share the net first so the Codebook encoding stores every
        // sparse layer as a CodebookQ kernel, then check the plan against
        // the dense oracle over the same (quantized) weights
        let mut net = prune_qnetwork(&rand_qnet(quickstart(), 11), 0.9);
        for w in net.weights.iter_mut() {
            *w = crate::compress::codebook_quantize_matrix(w);
        }
        let model = crate::compress::CompressedModel::from_network_encoded(
            &net,
            0.75,
            crate::compress::ArtifactEncoding::Codebook,
            0.0,
            1.0,
            1.0,
        )
        .unwrap();
        let want = reference_forward_q(&net, &rand_x(5, 64, 12));
        for opts in [
            PlanOptions::default(),
            PlanOptions::default().with_reorder_rows(true),
            PlanOptions::default().with_activation_skip(false),
            PlanOptions::default().with_threads(3).with_reorder_rows(true),
        ] {
            let mut plan = ExecPlan::compile_artifact_with(&model, &opts).unwrap();
            assert_eq!(plan.kernels(), vec![KernelKind::CodebookQ; 2], "{opts:?}");
            assert_eq!(plan.run(&rand_x(5, 64, 12)).unwrap().data, want.data, "{opts:?}");
        }
    }

    #[test]
    fn reorder_and_activation_skip_are_bit_identical() {
        // heavily pruned net + ReLU hidden layers + inputs with dead
        // columns: both the row permutation and the column mask engage,
        // and neither may change a single bit
        let net = prune_qnetwork(&rand_qnet(quickstart(), 13), 0.9);
        let mut x = rand_x(6, 64, 14);
        for r in 0..x.rows {
            for c in 0..x.cols {
                if c % 3 != 0 {
                    x.data[r * x.cols + c] = 0;
                }
            }
        }
        let want = reference_forward_q(&net, &x);
        for opts in [
            PlanOptions::sparse_always(),
            PlanOptions::sparse_always().with_reorder_rows(true),
            PlanOptions::sparse_always().with_activation_skip(false),
            PlanOptions::sparse_always().with_reorder_rows(true).with_threads(3),
        ] {
            let mut plan = ExecPlan::compile_q(&net, &opts).unwrap();
            assert_eq!(plan.run(&x).unwrap().data, want.data, "{opts:?}");
        }
    }

    #[test]
    fn profile_records_per_layer_kernels_and_mask() {
        // sparse plan + dead input columns so the activation mask engages
        // on layer 1; the profile must see both layers, the mask, and a
        // post-mask nnz strictly below the full count
        let net = prune_qnetwork(&rand_qnet(quickstart(), 21), 0.9);
        let mut x = rand_x(6, 64, 22);
        for r in 0..x.rows {
            for c in 0..x.cols {
                if c % 3 != 0 {
                    x.data[r * x.cols + c] = 0;
                }
            }
        }
        let opts = PlanOptions::sparse_always().with_profile(true);
        let mut plan = ExecPlan::compile_q(&net, &opts).unwrap();
        assert_eq!(plan.profile().unwrap().batches(), 0);
        let want = reference_forward_q(&net, &x);
        for _ in 0..3 {
            assert_eq!(plan.run(&x).unwrap().data, want.data);
        }
        let p = plan.profile().unwrap();
        assert_eq!(p.batches(), 3);
        assert_eq!(p.layers.len(), 2);
        for l in &p.layers {
            assert_eq!(l.kernel, KernelKind::SparseQ);
            assert_eq!(l.runs, 3);
            assert_eq!(l.items, 18);
        }
        let full_nnz: usize = net.weights[1].data.iter().filter(|&&v| v != 0).count();
        let l1 = &p.layers[1];
        if l1.masked_runs > 0 {
            assert!(l1.cols_skipped > 0);
            assert!((l1.mean_nnz() as usize) < full_nnz, "mask must cut nnz");
        }
        // a profile-off plan stays unprofiled and bit-identical
        let mut off = ExecPlan::compile_q(&net, &PlanOptions::sparse_always()).unwrap();
        assert!(off.profile().is_none());
        assert_eq!(off.run(&x).unwrap().data, want.data);
        // clone_shared gives the twin a fresh recorder
        let twin = plan.clone_shared();
        assert_eq!(twin.profile().unwrap().batches(), 0);
    }

    #[test]
    fn run_reuses_buffers_across_calls() {
        let net = rand_qnet(quickstart(), 4);
        let mut plan = ExecPlan::compile_q(&net, &PlanOptions::default()).unwrap();
        let x = rand_x(8, 64, 5);
        let p0 = plan.run(&x).unwrap().data.as_ptr();
        let p1 = plan.run(&x).unwrap().data.as_ptr();
        assert_eq!(p0, p1, "second run must reuse the same activation buffer");
    }

    #[test]
    fn plan_validates_input_and_numeric_path() {
        let net = rand_qnet(quickstart(), 6);
        let mut plan = ExecPlan::compile_q(&net, &PlanOptions::default()).unwrap();
        assert!(plan.run(&MatI::zeros(1, 63)).is_err());
        assert!(plan.run_f32(&MatF::zeros(1, 64)).is_err());
        let spec = quickstart();
        let wf: Vec<MatF> = spec
            .weight_shapes()
            .iter()
            .map(|&(o, i)| MatF::zeros(o, i))
            .collect();
        let mut fplan = ExecPlan::compile_f32(&spec, &wf).unwrap();
        assert_eq!(fplan.kernels(), vec![KernelKind::DenseF32; 2]);
        assert!(fplan.run(&MatI::zeros(1, 64)).is_err());
        assert!(fplan.run_f32(&MatF::zeros(1, 64)).is_ok());
        assert!(ExecPlan::compile_f32(&spec, &wf[..1]).is_err());
    }

    #[test]
    fn clone_shared_shares_weights_but_not_buffers() {
        let net = prune_qnetwork(&rand_qnet(quickstart(), 7), 0.9);
        let mut plan = ExecPlan::compile_q(&net, &PlanOptions::default()).unwrap();
        let mut twin = plan.clone_shared();
        // kernel storage is shared: same Arc allocation per layer
        for (a, b) in plan.layers.iter().zip(twin.layers.iter()) {
            match (&a.kernel, &b.kernel) {
                (Kernel::DenseQ(x), Kernel::DenseQ(y)) => assert!(Arc::ptr_eq(x, y)),
                (Kernel::SparseQ(x), Kernel::SparseQ(y)) => assert!(Arc::ptr_eq(x, y)),
                (Kernel::CodebookQ(x), Kernel::CodebookQ(y)) => assert!(Arc::ptr_eq(x, y)),
                (Kernel::DenseF32(x), Kernel::DenseF32(y)) => assert!(Arc::ptr_eq(x, y)),
                _ => panic!("clone changed kernel kinds"),
            }
        }
        // outputs bit-identical, activation buffers independent
        let x = rand_x(4, 64, 8);
        let a = plan.run(&x).unwrap();
        let b = twin.run(&x).unwrap();
        assert_eq!(a.data, b.data);
        assert_ne!(a.data.as_ptr(), b.data.as_ptr(), "buffers must not be shared");
    }

    #[test]
    fn prop_plan_bit_identical_for_random_nets() {
        // random architectures, prune factors, thresholds, batch sizes, and
        // thread counts — every plan must match the naive dense oracle
        prop_check(25, |g| {
            let depth = g.usize(2..5);
            let sizes: Vec<usize> = (0..depth).map(|_| g.usize(1..24)).collect();
            let spec = NetworkSpec::new("prop", &sizes);
            let q = g.f64(0.0, 1.0);
            let seed = g.u64(0..=u64::MAX / 2);
            let net = prune_qnetwork(&rand_qnet(spec, seed), q);
            let n = g.usize(1..7);
            let x = rand_x(n, sizes[0], seed ^ 1);
            let want = reference_forward_q(&net, &x);
            let opts = PlanOptions {
                sparse_threshold: g.f64(0.0, 1.2),
                threads: g.usize(1..4),
                reorder_rows: g.bool(0.5),
                activation_skip: g.bool(0.5),
                profile: g.bool(0.5),
            };
            let mut plan = match ExecPlan::compile_q(&net, &opts) {
                Ok(p) => p,
                Err(_) => return false,
            };
            plan.run(&x).unwrap().data == want.data
        });
    }
}
