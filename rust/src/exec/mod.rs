//! Compiled execution plans: per-layer kernel selection behind one
//! [`ExecPlan`].
//!
//! The paper's two headline optimizations — batch processing (§5.5) and
//! pruned weight streams (§5.6) — used to live on disjoint code paths
//! here: `nn::forward` was dense-only and the sparse tuple format was
//! consumed only by the cycle-level simulator, so a pruned network gained
//! nothing on the actual serving path.  This module makes the dense/sparse
//! choice an explicit *compilation* decision (the framing of the FPGA
//! accelerator surveys): a plan is compiled **once** from a network and
//! then executed per batch with zero per-layer allocation.
//!
//! # Kernel-selection policy
//!
//! For every layer transition the compiler measures the pruning factor
//! `q_prune^(j)` (fraction of zero weights) and picks:
//!
//! * **`SparseQ`** when `q_prune^(j)` ≥ [`PlanOptions::sparse_threshold`]
//!   (default [`DEFAULT_SPARSE_THRESHOLD`]) — the weights are encoded into
//!   the §5.6 `(w, z)` tuple stream and lowered to a CSR view
//!   ([`crate::sparse::SparseMatrix::to_csr`]), and the layer executes
//!   directly on the compressed representation
//!   ([`crate::tensor::spmm_i32`]).  Work scales with the *remaining*
//!   weights, so a q = 0.9 layer does ~10 % of the dense MACs.
//! * **`DenseQ`** otherwise — the register-blocked wrapping-i32 GEMM
//!   ([`crate::tensor::gemm_i32`]).  Below the threshold the sparse
//!   format's per-non-zero indexing overhead outweighs the skipped MACs.
//! * **`CodebookQ`** for `.rpz` layers stored with EIE weight sharing —
//!   CSR positions plus 4-bit codes into a 16-entry value LUT
//!   ([`crate::tensor::spmm_codebook_i32`]); same work scaling as
//!   `SparseQ`, ~¼ the value bytes.
//! * **`DenseF32`** for plans compiled from float weights (the software
//!   baseline path); no sparse variant exists because pruning is a
//!   quantized-deployment technique in the paper.
//!
//! Compressed `.rpz` artifacts ([`crate::compress`]) short-circuit the
//! policy: [`ExecPlan::compile_artifact`] maps each stored blob to its
//! kernel directly (CSR/delta-CSR → `SparseQ`, codebook → `CodebookQ`,
//! dense → `DenseQ`), so the calibrated threshold embedded at compression
//! time *is* the kernel decision — no `--threshold` flag at serve time.
//!
//! Two further EIE-style refinements apply to the sparse-family kernels:
//!
//! * **Row reordering** ([`PlanOptions::reorder_rows`]) sorts CSR rows by
//!   descending non-zero count at compile time and un-permutes outputs
//!   through a stored index — better locality and parallel balance, same
//!   bits.
//! * **Activation skipping** ([`PlanOptions::activation_skip`], default
//!   on): after a ReLU layer the runtime builds a non-zero-column mask of
//!   the activation batch and the sparse kernels skip dead columns
//!   entirely; engaged per batch only when the zero-column fraction
//!   reaches [`ACT_SKIP_MIN_ZERO_FRAC`].
//!
//! All Q kernels use wrapping i32 accumulation, which is associative and
//! commutative mod 2^32 — so every plan, any thread count, any kernel mix,
//! any reorder/skip setting, is **bit-identical** to the golden dense
//! model (property-tested in [`plan`]).
//!
//! # Execution
//!
//! The plan owns two ping-pong activation buffers sized to the widest
//! layer and an optional shared [`ThreadPool`](crate::util::threadpool::ThreadPool);
//! `run` borrows the input, alternates layer outputs between the two
//! buffers, and returns a reference into the plan — no `MatI::zeros` (or
//! any other) allocation inside the per-layer loop.
//!
//! ```ignore
//! let mut plan = ExecPlan::compile_q(&net, &PlanOptions::default())?;
//! let y = plan.run(&x)?; // &MatI borrowed from the plan's buffers
//! ```

pub mod plan;

pub use plan::{
    ExecPlan, KernelKind, PlanOptions, ACT_SKIP_MIN_ZERO_FRAC, DEFAULT_SPARSE_THRESHOLD,
};
