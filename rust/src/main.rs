//! `zynq-dnn` — CLI for the FPGA-DNN-inference reproduction.
//!
//! Subcommands:
//!   info                         device/resource/calibration summary
//!   train                        train + prune + save a network
//!   compress                     accuracy-budgeted pruning -> .rpz artifact
//!                                (sensitivity sweep + per-layer search)
//!   infer                        run one inference through a backend
//!   serve                        demo serving loop with the dynamic batcher
//!                                (delegates to the sharded pool when --workers > 1;
//!                                --listen exposes either stack over TCP with
//!                                INFER / INFER BULK priorities on the wire;
//!                                --models name=path.rpz[@share],... serves a
//!                                multi-model registry with INFER @<model>
//!                                routing, MODELS, and zero-downtime SWAP)
//!   swap                         hot-swap a model on a running registry server:
//!                                zynq-dnn swap <model> <path.rpz> [--connect a:p]
//!   serve-pool                   sharded pool demo: mixed-priority traffic,
//!                                per-shard + aggregate metrics
//!   sim                          simulate one network on both accelerators
//!   profile                      per-layer kernel profile of a compiled plan
//!                                (the runtime twin of the paper's Fig. 7
//!                                layer breakdown; takes --artifact/--network)
//!   bench <which>                regenerate a paper table/figure, or run the
//!                                serving benches (table2|table3|table4|fig7|
//!                                gops|nopt|combined|ablation|sparse|slo|
//!                                calibrate|compress|net|obs|registry|sim|
//!                                autoscale|all); sparse/slo/compress/net/
//!                                obs/registry/sim/autoscale also write
//!                                BENCH_<which>.json
//!
//! `infer`, `serve`, `serve-pool`, and `profile` take `--artifact model.rpz`
//! to serve a compressed model directly: the network weights AND the
//! calibrated sparse threshold come from the artifact (no `--threshold`
//! needed).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use zynq_dnn::bench;
use zynq_dnn::cli::{parse, usage, Args, FlagSpec};
use zynq_dnn::compress::{
    accuracy_q, save_artifact, ArtifactEncoding, CompressedModel, EvalSet, SearchConfig,
    DEFAULT_LADDER,
};
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::{EngineFactory, Server, SubmitOptions, SubmitTarget};
use zynq_dnn::exec::{ExecPlan, PlanOptions};
use zynq_dnn::serve::{start_serving, Priority, Serving};
use zynq_dnn::nn::spec::by_name;
use zynq_dnn::nn::{load_weights, save_weights};
use zynq_dnn::sim::batch::BatchAccelerator;
use zynq_dnn::sim::pruning::{prune_qnetwork, PruningAccelerator, SparseNetwork};
use zynq_dnn::sim::resources::{batch_design_resources, pruning_design_resources};
use zynq_dnn::sim::zynq::XC7020;
use zynq_dnn::train::prune::apply_pruning;
use zynq_dnn::train::{evaluate_f32, evaluate_q, TrainConfig, Trainer};
use zynq_dnn::util::rng::Xoshiro256;

const GLOBAL_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        name: "network",
        takes_value: true,
        help: "network name (mnist4|mnist8|har4|har6|quickstart)",
    },
    FlagSpec {
        name: "batch",
        takes_value: true,
        help: "batch size",
    },
    FlagSpec {
        name: "backend",
        takes_value: true,
        help: "pjrt|native|native-sparse|sim|sim-batch|sim-prune \
               (sim = serving-grade simulated ZedBoard: plan outputs, modeled latency)",
    },
    FlagSpec {
        name: "weights",
        takes_value: true,
        help: "path to a .zdnw weight file",
    },
    FlagSpec {
        name: "out",
        takes_value: true,
        help: "output path",
    },
    FlagSpec {
        name: "epochs",
        takes_value: true,
        help: "training epochs",
    },
    FlagSpec {
        name: "samples",
        takes_value: true,
        help: "training samples",
    },
    FlagSpec {
        name: "prune",
        takes_value: true,
        help: "pruning factor (0..1)",
    },
    FlagSpec {
        name: "requests",
        takes_value: true,
        help: "requests for the serve demo",
    },
    FlagSpec {
        name: "deadline-us",
        takes_value: true,
        help: "batcher deadline",
    },
    FlagSpec {
        name: "quick",
        takes_value: false,
        help: "shrink expensive runs",
    },
    FlagSpec {
        name: "artifacts",
        takes_value: true,
        help: "artifacts directory",
    },
    FlagSpec {
        name: "listen",
        takes_value: true,
        help: "serve: expose the TCP line protocol on this address (e.g. 127.0.0.1:7878); \
               with --workers N the socket fronts the sharded pool",
    },
    FlagSpec {
        name: "workers",
        takes_value: true,
        help: "serving shards (1 = single engine)",
    },
    FlagSpec {
        name: "policy",
        takes_value: true,
        help: "shard selection: round-robin|least-loaded|p2c",
    },
    FlagSpec {
        name: "promote-us",
        takes_value: true,
        help: "bulk aging threshold before promotion \
               (0 = adapt to the measured interactive arrival rate, the default)",
    },
    FlagSpec {
        name: "autoscale",
        takes_value: false,
        help: "serve: grow/park pool shards from queue depth + the perfmodel \
               service-time prediction (exports zdnn_autoscale_* counters)",
    },
    FlagSpec {
        name: "autoscale-target-p99-us",
        takes_value: true,
        help: "autoscale: queueing-delay budget the controller sizes for (default 5000)",
    },
    FlagSpec {
        name: "autoscale-min-workers",
        takes_value: true,
        help: "autoscale: floor the pool parks down to (default 1)",
    },
    FlagSpec {
        name: "autoscale-max-workers",
        takes_value: true,
        help: "autoscale: provisioned ceiling (default 0 = --workers)",
    },
    FlagSpec {
        name: "interactive-every",
        takes_value: true,
        help: "serve-pool: every k-th request is interactive",
    },
    FlagSpec {
        name: "threshold",
        takes_value: true,
        help: "native backend: sparse kernel threshold override (see bench calibrate)",
    },
    FlagSpec {
        name: "artifact",
        takes_value: true,
        help: "serve/infer a compressed .rpz model (embeds its own calibration)",
    },
    FlagSpec {
        name: "budget",
        takes_value: true,
        help: "compress: max tolerated accuracy drop vs the dense baseline",
    },
    FlagSpec {
        name: "calibrate",
        takes_value: false,
        help: "compress: measure the dense/CSR crossover and embed it as the threshold",
    },
    FlagSpec {
        name: "encoding",
        takes_value: true,
        help: "compress: sparse-layer artifact encoding: raw|delta|codebook (default delta; \
               codebook adds the accuracy-budgeted weight-sharing rung)",
    },
    FlagSpec {
        name: "runs",
        takes_value: true,
        help: "profile: batches to execute through the plan",
    },
    FlagSpec {
        name: "threads",
        takes_value: true,
        help: "profile: worker threads for the parallel kernels",
    },
    FlagSpec {
        name: "trace-sample",
        takes_value: true,
        help: "serve: trace every n-th request id (1 = all, 0 = off); \
               query with TRACE #<id> / TRACE LAST <n> on the wire",
    },
    FlagSpec {
        name: "models",
        takes_value: true,
        help: "serve: multi-model registry, comma list of name=path.rpz[@share] \
               (requires --listen; route with INFER @<model> on the wire)",
    },
    FlagSpec {
        name: "default-model",
        takes_value: true,
        help: "serve: model that plain INFER (no @<model>) routes to \
               (default: first entry of --models)",
    },
    FlagSpec {
        name: "connect",
        takes_value: true,
        help: "swap: address of the running registry server \
               (default 127.0.0.1:7878)",
    },
    FlagSpec {
        name: "wire",
        takes_value: true,
        help: "serve: newest wire generation to accept — v3 (default) serves \
               binary frames alongside v1/v2 text; v2 refuses binary frames",
    },
    FlagSpec {
        name: "max-conns",
        takes_value: true,
        help: "serve: open-connection cap; accepts past it get one ERR busy \
               line and a close (default 4096)",
    },
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = parse(argv, GLOBAL_FLAGS)?;
    if args.has("quick") {
        std::env::set_var("ZDNN_QUICK", "1");
    }
    let cmd = args.positionals.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => info(),
        "train" => train(&args),
        "compress" => compress(&args),
        "infer" => infer(&args),
        "serve" => serve(&args),
        "serve-pool" => serve_pool(&args),
        "swap" => swap_cmd(&args),
        "sim" => sim(&args),
        "profile" => profile(&args),
        "bench" => run_bench(&args),
        _ => {
            println!("zynq-dnn — FPGA DNN inference throughput reproduction\n");
            println!(
                "usage: zynq-dnn <info|train|compress|infer|serve|serve-pool|swap|sim|profile|\
                 bench> [flags]\n"
            );
            println!("{}", usage(GLOBAL_FLAGS));
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(zynq_dnn::runtime::default_artifacts_dir)
}

fn sparse_threshold(args: &Args) -> Result<Option<f64>> {
    Ok(match args.get("threshold") {
        Some(v) => Some(v.parse().with_context(|| format!("--threshold: bad number {v:?}"))?),
        None => None,
    })
}

fn info() -> Result<()> {
    println!("device: Zynq XC7020 (ZedBoard)");
    println!(
        "  DSP {}  BRAM36 {}  LUT {}  FF {}  HP-ports {}",
        XC7020.dsp_slices, XC7020.bram36, XC7020.luts, XC7020.flip_flops, XC7020.hp_ports
    );
    let mem = zynq_dnn::sim::memory::MemoryModel::zedboard();
    println!(
        "memory: HP peak {:.2} GB/s, effective {:.2} GB/s (calibrated)",
        mem.hp_peak / 1e9,
        mem.effective() / 1e9
    );
    println!("batch-design builds:");
    for &(n, _) in zynq_dnn::sim::resources::PAPER_BATCH_MACS {
        let r = batch_design_resources(&XC7020, n);
        println!(
            "  n={n:<3} m={:<4} dsp={:<4} bram18={:<4} lut={} fits={}",
            r.macs,
            r.dsp_slices,
            r.bram18,
            r.luts,
            r.fits(&XC7020)
        );
    }
    let p = pruning_design_resources(&XC7020, 4, 3);
    println!(
        "pruning design: m=4 r=3 -> {} MACs, bram18={}, fits={}",
        p.macs,
        p.bram18,
        p.fits(&XC7020)
    );
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let name = args.get_or("network", "quickstart");
    let spec = by_name(name)?;
    let quick = bench::quick_mode();
    let samples = args.get_usize("samples", if quick { 400 } else { 1500 })?;
    let epochs = args.get_usize("epochs", if quick { 3 } else { 8 })?;
    let prune = args.get_f64("prune", 0.0)?;
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{name}.zdnw")));

    let data = zynq_dnn::data::for_network(name, samples, 0x5EED)?;
    let test = zynq_dnn::data::for_network(name, samples / 3, 0x7E57)?;
    eprintln!(
        "training {name} ({}) on {} synthetic samples, {} epochs",
        spec.abbrev(),
        data.len(),
        epochs
    );
    let mut trainer = Trainer::new(spec, 0xACC);
    let cfg = TrainConfig {
        epochs,
        verbose: true,
        ..Default::default()
    };
    trainer.fit(&data, &cfg)?;
    let base_f = evaluate_f32(&trainer.to_weights(), &test);
    let base_q = evaluate_q(&trainer.to_weights(), &test);
    eprintln!("baseline accuracy: f32 {base_f:.3}, Q7.8 {base_q:.3}");

    if prune > 0.0 {
        let report = apply_pruning(&mut trainer, prune)?;
        eprintln!(
            "pruned to {:.3} (target {prune}); retraining…",
            report.achieved
        );
        trainer.fit(
            &data,
            &TrainConfig {
                epochs: (epochs / 2).max(1),
                learning_rate: 0.015,
                verbose: true,
                ..Default::default()
            },
        )?;
        let acc = evaluate_q(&trainer.to_weights(), &test);
        eprintln!("pruned accuracy: Q7.8 {acc:.3} (Δ {:+.3})", acc - base_q);
    }

    save_weights(&out, &trainer.to_weights())?;
    eprintln!("saved {}", out.display());
    Ok(())
}

/// `compress`: sensitivity sweep + accuracy-budgeted search + `.rpz` save.
fn compress(args: &Args) -> Result<()> {
    let name = args.get_or("network", "quickstart");
    let net = load_or_random(args, name)?;
    let name = net.spec.name.clone(); // --weights may carry its own name
    let quick = bench::quick_mode();
    let samples = args.get_usize("samples", if quick { 200 } else { 600 })?;
    let budget = args.get_f64("budget", 0.02)?;
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{name}.rpz")));

    // search slice + a disjoint verify slice (different seed) so the
    // summary reports how the budget generalizes
    let search_data = zynq_dnn::data::for_network(&name, samples, 0xC0_5EED)?;
    let verify_data = zynq_dnn::data::for_network(&name, (samples / 2).max(1), 0xC0_7E57)?;
    let eval = EvalSet::from_dataset(&search_data);
    let verify = EvalSet::from_dataset(&verify_data);

    eprintln!(
        "compressing {name} ({}): budget {budget}, {} search + {} verify samples",
        net.spec.abbrev(),
        eval.len(),
        verify.len()
    );
    let report = zynq_dnn::compress::sweep(&net, &eval, &DEFAULT_LADDER)?;
    println!("{}", report.render());

    let encoding = ArtifactEncoding::from_name(args.get_or("encoding", "delta"))?;
    let cfg = SearchConfig {
        budget,
        ladder: DEFAULT_LADDER.to_vec(),
        encoding,
    };
    let outcome = zynq_dnn::compress::search(&net, &eval, &report, &cfg)?;
    for (j, (&target, &achieved)) in outcome
        .factors
        .iter()
        .zip(outcome.achieved.iter())
        .enumerate()
    {
        eprintln!("  layer {j}: target {target:.2}, achieved {achieved:.3}");
    }

    // threshold precedence: --threshold > --calibrate measurement > default
    let threshold = match sparse_threshold(args)? {
        Some(t) => t,
        None if args.has("calibrate") => {
            eprintln!("calibrating dense/CSR crossover…");
            let c = bench::calibrate::run();
            match c.crossover() {
                Some(q) => q,
                None => {
                    eprintln!(
                        "  no crossover measured; keeping default {}",
                        zynq_dnn::exec::DEFAULT_SPARSE_THRESHOLD
                    );
                    zynq_dnn::exec::DEFAULT_SPARSE_THRESHOLD
                }
            }
        }
        None => zynq_dnn::exec::DEFAULT_SPARSE_THRESHOLD,
    };
    let model = CompressedModel::from_outcome(&outcome, threshold)?;
    save_artifact(&out, &model)?;

    let verify_base = accuracy_q(&net, &verify)?;
    let verify_comp = accuracy_q(&outcome.network, &verify)?;
    println!(
        "compressed {name}: prune {:.3}, accuracy {:.3} -> {:.3} (Δ {:+.3}, budget {budget}); \
         held-out {:.3} -> {:.3}",
        outcome.overall_prune(),
        outcome.baseline_accuracy,
        outcome.compressed_accuracy,
        -outcome.accuracy_delta(),
        verify_base,
        verify_comp,
    );
    println!(
        "artifact {}: threshold {threshold:.2}, encoding {}, payload {} B \
         (raw CSR {} B) vs {} B dense ({:.2}x); \
         serve it with: zynq-dnn serve-pool --artifact {}",
        out.display(),
        encoding.name(),
        model.stored_bytes(),
        model.raw_stored_bytes(),
        model.dense_bytes(),
        model.compression_ratio(),
        out.display(),
    );
    Ok(())
}

fn load_or_random(args: &Args, name: &str) -> Result<zynq_dnn::nn::QNetwork> {
    match args.get("weights") {
        Some(path) => Ok(load_weights(&PathBuf::from(path))?.quantized()),
        None => {
            let spec = by_name(name)?;
            Ok(bench::random_qnet(&spec, 0xD1CE))
        }
    }
}

/// Engine factory for `infer`/`serve`/`serve-pool`: from `--artifact` (a
/// compressed `.rpz` model carrying its own calibrated threshold) or from
/// `--weights` / a seeded random net.  Returns the factory and the
/// network name to report.  An explicit `--threshold` always wins — with
/// an artifact it recompiles the reconstructed network at that threshold
/// instead of trusting the embedded calibration.
fn build_factory(args: &Args, backend: &str, batch: usize) -> Result<(EngineFactory, String)> {
    let threshold = sparse_threshold(args)?;
    if let Some(path) = args.get("artifact") {
        let mut factory = EngineFactory::for_artifact(
            Path::new(path),
            backend,
            batch,
            artifacts_dir(args),
            1,
        )?;
        factory.sparse_threshold = threshold;
        let model = factory.artifact.clone().expect("for_artifact sets it");
        eprintln!(
            "artifact {path}: {} ({}), prune {:.3}, threshold {:.2}, \
             accuracy {:.3} (baseline {:.3}, budget {:.3}), payload {} B ({:.2}x dense)",
            model.spec.name,
            model.spec.abbrev(),
            factory.net.overall_prune_factor(),
            model.sparse_threshold,
            model.compressed_accuracy,
            model.baseline_accuracy,
            model.budget,
            model.stored_bytes(),
            model.compression_ratio(),
        );
        let name = factory.net.spec.name.clone();
        Ok((factory, name))
    } else {
        let name = args.get_or("network", "quickstart").to_string();
        let net = load_or_random(args, &name)?;
        let factory = EngineFactory {
            backend: backend.into(),
            batch,
            net,
            artifacts_dir: artifacts_dir(args),
            native_threads: 1,
            sparse_threshold: threshold,
            artifact: None,
        };
        Ok((factory, name))
    }
}

fn infer(args: &Args) -> Result<()> {
    let batch = args.get_usize("batch", 1)?;
    let backend = args.get_or("backend", "native");
    let (factory, _name) = build_factory(args, backend, batch)?;
    let s_in = factory.net.spec.inputs();
    let mut engine = factory.build()?;
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut x = zynq_dnn::tensor::MatI::zeros(batch, s_in);
    for v in x.data.iter_mut() {
        *v = zynq_dnn::fixedpoint::quantize(rng.uniform(-1.0, 1.0));
    }
    let (y, secs) = zynq_dnn::util::timed(|| engine.infer(&x));
    let y = y?;
    println!(
        "backend={backend} batch={batch} -> output {:?} in {}",
        y.shape(),
        zynq_dnn::util::fmt_time(secs)
    );
    if let Some(sim) = engine.simulated_seconds() {
        println!(
            "simulated accelerator time: {} ({} per sample)",
            zynq_dnn::util::fmt_time(sim),
            zynq_dnn::util::fmt_time(sim / batch as f64)
        );
    }
    for (r, class) in zynq_dnn::nn::forward::argmax_rows(&y)
        .iter()
        .enumerate()
        .take(4)
    {
        println!("  sample {r}: class {class}");
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let batch = args.get_usize("batch", 4)?;
    let backend = args.get_or("backend", "native");
    let requests = args.get_usize("requests", 64)?;
    let deadline = args.get_usize("deadline-us", 2000)? as u64;
    let workers = args.get_usize("workers", 1)?;

    if let Some(models) = args.get("models") {
        // registry mode: many named .rpz replica sets behind one socket,
        // with INFER @<model> routing, MODELS, and zero-downtime SWAP
        let Some(listen) = args.get("listen") else {
            bail!("--models serves over TCP only; add --listen <addr:port>");
        };
        let policy = args.get_or("policy", "round-robin");
        let promote = args.get_usize("promote-us", 0)? as u64;
        let cfg = ServerConfig {
            batch,
            batch_deadline_us: deadline,
            workers: workers.max(1),
            policy: policy.into(),
            bulk_promote_us: promote,
            backend: backend.into(),
            artifacts_dir: artifacts_dir(args).display().to_string(),
            listen: listen.to_string(),
            trace_sample: args.get_usize("trace-sample", 1)? as u64,
            models: models.to_string(),
            default_model: args.get("default-model").unwrap_or("").to_string(),
            wire: args.get_or("wire", "v3").to_string(),
            max_conns: args.get_usize("max-conns", 4096)?,
            ..Default::default()
        };
        cfg.validate()?;
        let registry = std::sync::Arc::new(zynq_dnn::registry::Registry::start(&cfg)?);
        eprintln!(
            "registry: {} model(s), {} replica(s) over a {}-worker budget on {backend}, \
             default model {:?}",
            registry.len(),
            registry.replicas_total(),
            cfg.workers,
            registry.default_model()
        );
        for line in registry.model_lines() {
            eprintln!("  {line}");
        }
        let fe = zynq_dnn::coordinator::NetFrontend::start_with(
            &cfg.listen,
            registry,
            zynq_dnn::coordinator::NetOptions {
                max_conns: cfg.max_conns,
                accept_v3: cfg.wire == "v3",
            },
        )?;
        eprintln!(
            "listening on {} — wire {} + registry (max_conns {}): binary v3 frames + \
             INFER [@<model>] [BULK] [#<id>] <f32>... | MODELS | SWAP <model> <path.rpz> | \
             STATS [JSON|PROM] | TRACE #<id> | TRACE LAST <n> | QUIT",
            fe.addr(),
            cfg.wire,
            cfg.max_conns
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    if let Some(listen) = args.get("listen") {
        // TCP mode: the frontend drives whichever SubmitTarget the worker
        // count selects — single engine or sharded pool — with the
        // Interactive/Bulk classes on the wire; block until Ctrl-C
        let policy = args.get_or("policy", "round-robin");
        let promote = args.get_usize("promote-us", 0)? as u64;
        let (factory, name) = build_factory(args, backend, batch)?;
        let cfg = ServerConfig {
            network: name.clone(),
            batch,
            batch_deadline_us: deadline,
            workers,
            policy: policy.into(),
            bulk_promote_us: promote,
            backend: backend.into(),
            artifact: args.get("artifact").unwrap_or("").to_string(),
            listen: listen.to_string(),
            trace_sample: args.get_usize("trace-sample", 1)? as u64,
            wire: args.get_or("wire", "v3").to_string(),
            max_conns: args.get_usize("max-conns", 4096)?,
            autoscale: args.has("autoscale"),
            autoscale_target_p99_us: args.get_usize("autoscale-target-p99-us", 5_000)? as u64,
            autoscale_min_workers: args.get_usize("autoscale-min-workers", 1)?,
            autoscale_max_workers: args.get_usize("autoscale-max-workers", 0)?,
            ..Default::default()
        };
        cfg.validate()?;
        let serving = std::sync::Arc::new(start_serving(&cfg, factory)?);
        eprintln!(
            "serving {name} on {backend}, {} worker(s), batch {batch}, deadline {deadline} µs{}",
            serving.workers(),
            if cfg.autoscale {
                format!(
                    " (autoscale on: {}..{} workers, target p99 {} µs)",
                    cfg.autoscale_min_workers,
                    zynq_dnn::serve::autoscale::effective_max(&cfg),
                    cfg.autoscale_target_p99_us
                )
            } else {
                String::new()
            }
        );
        let fe = zynq_dnn::coordinator::NetFrontend::start_with(
            &cfg.listen,
            serving,
            zynq_dnn::coordinator::NetOptions {
                max_conns: cfg.max_conns,
                accept_v3: cfg.wire == "v3",
            },
        )?;
        eprintln!(
            "listening on {} — wire {} (max_conns {}): binary v3 frames (0x00 magic) + \
             INFER [BULK] [#<id>] <f32>... | STATS [JSON|PROM] | TRACE #<id> | \
             TRACE LAST <n> | QUIT \
             (tagged requests pipeline with out-of-order replies; \
             untagged requests keep v1 lockstep)",
            fe.addr(),
            cfg.wire,
            cfg.max_conns
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    if workers > 1 {
        // no socket requested: the sharded path has its own local demo
        return serve_pool(args);
    }
    let (factory, name) = build_factory(args, backend, batch)?;
    let s_in = factory.net.spec.inputs();

    let cfg = ServerConfig {
        network: name.clone(),
        batch,
        batch_deadline_us: deadline,
        backend: backend.into(),
        artifact: args.get("artifact").unwrap_or("").to_string(),
        ..Default::default()
    };
    let server = Server::start(&cfg, factory)?;
    eprintln!("serving {name} on {backend}, batch {batch}, deadline {deadline} µs");

    let mut rng = Xoshiro256::seed_from_u64(2);
    let mut tickets = Vec::new();
    for _ in 0..requests {
        let input: Vec<i32> = (0..s_in)
            .map(|_| zynq_dnn::fixedpoint::quantize(rng.uniform(-1.0, 1.0)))
            .collect();
        tickets.push(server.submit(input, SubmitOptions::default())?);
    }
    let mut classes = vec![0usize; 10];
    for mut ticket in tickets {
        let resp = ticket.wait()?;
        if resp.class < classes.len() {
            classes[resp.class] += 1;
        }
    }
    let snap = server.metrics.snapshot();
    println!(
        "served {} requests in {} batches; occupancy {:.2}; mean latency {}; p95 {}; \
         throughput {:.0}/s",
        snap.requests,
        snap.batches,
        snap.occupancy,
        zynq_dnn::util::fmt_time(snap.mean_latency_s),
        zynq_dnn::util::fmt_time(snap.p95_latency_s),
        snap.throughput
    );
    println!("class histogram: {classes:?}");
    server.shutdown()?;
    Ok(())
}

fn serve_pool(args: &Args) -> Result<()> {
    let batch = args.get_usize("batch", 4)?;
    let backend = args.get_or("backend", "native");
    let requests = args.get_usize("requests", 256)?;
    let deadline = args.get_usize("deadline-us", 2000)? as u64;
    let workers = args.get_usize("workers", 4)?;
    let policy = args.get_or("policy", "round-robin");
    let promote = args.get_usize("promote-us", 0)? as u64;
    let every = args.get_usize("interactive-every", 5)?.max(1);
    let (factory, name) = build_factory(args, backend, batch)?;
    let s_in = factory.net.spec.inputs();

    let cfg = ServerConfig {
        network: name.clone(),
        batch,
        batch_deadline_us: deadline,
        workers,
        policy: policy.into(),
        bulk_promote_us: promote,
        queue_depth: requests.max(1024),
        backend: backend.into(),
        artifact: args.get("artifact").unwrap_or("").to_string(),
        trace_sample: args.get_usize("trace-sample", 1)? as u64,
        autoscale: args.has("autoscale"),
        autoscale_target_p99_us: args.get_usize("autoscale-target-p99-us", 5_000)? as u64,
        autoscale_min_workers: args.get_usize("autoscale-min-workers", 1)?,
        autoscale_max_workers: args.get_usize("autoscale-max-workers", 0)?,
        ..Default::default()
    };
    cfg.validate()?;
    let serving = start_serving(&cfg, factory)?;
    eprintln!(
        "pool: {name} on {backend}, {} worker(s), batch {batch}, policy {policy}, \
         1/{every} interactive",
        serving.workers()
    );

    let mut rng = Xoshiro256::seed_from_u64(2);
    let mut tickets = Vec::new();
    for i in 0..requests {
        let input: Vec<i32> = (0..s_in)
            .map(|_| zynq_dnn::fixedpoint::quantize(rng.uniform(-1.0, 1.0)))
            .collect();
        let prio = if i % every == 0 {
            Priority::Interactive
        } else {
            Priority::Bulk
        };
        tickets.push(serving.submit(input, SubmitOptions::with_priority(prio))?);
    }
    for mut ticket in tickets {
        ticket.wait()?;
    }

    match &serving {
        Serving::Pool(pool) => {
            let snap = pool.snapshot();
            for (i, s) in snap.shards.iter().enumerate() {
                println!(
                    "shard {i}: {} req in {} batches ({} padded, {} wasted slots), \
                     occupancy {:.2}, p99 {}",
                    s.requests,
                    s.batches,
                    s.padded_batches,
                    s.padded_slots,
                    s.occupancy,
                    zynq_dnn::util::fmt_time(s.p99_latency_s)
                );
            }
            let a = &snap.aggregate;
            println!(
                "aggregate: {} req; occupancy {:.2}; p50 {} p95 {} p99 {}; \
                 interactive p99 {} ({} req); bulk p99 {} ({} req, {} promoted); \
                 throughput {:.0}/s",
                a.requests,
                a.occupancy,
                zynq_dnn::util::fmt_time(a.p50_latency_s),
                zynq_dnn::util::fmt_time(a.p95_latency_s),
                zynq_dnn::util::fmt_time(a.p99_latency_s),
                zynq_dnn::util::fmt_time(a.interactive_p99_s),
                a.interactive_requests,
                zynq_dnn::util::fmt_time(a.bulk_p99_s),
                a.bulk_requests,
                a.promoted,
                a.throughput
            );
        }
        Serving::Single(server) => {
            let snap = server.metrics.snapshot();
            println!(
                "served {} requests in {} batches ({} padded, {} wasted slots); \
                 occupancy {:.2}; p95 {}; throughput {:.0}/s",
                snap.requests,
                snap.batches,
                snap.padded_batches,
                snap.padded_slots,
                snap.occupancy,
                zynq_dnn::util::fmt_time(snap.p95_latency_s),
                snap.throughput
            );
        }
    }
    serving.shutdown()?;
    Ok(())
}

/// `swap <model> <path.rpz>`: drive a zero-downtime hot swap on a running
/// `serve --models` frontend over the wire, then print the fresh model
/// listing.  Blocks until the server finishes draining the old version.
fn swap_cmd(args: &Args) -> Result<()> {
    let model = args
        .positionals
        .get(1)
        .context("usage: zynq-dnn swap <model> <path.rpz> [--connect addr:port]")?;
    let path = args
        .positionals
        .get(2)
        .context("usage: zynq-dnn swap <model> <path.rpz> [--connect addr:port]")?;
    let addr: std::net::SocketAddr = args
        .get_or("connect", "127.0.0.1:7878")
        .parse()
        .context("--connect: bad address")?;
    let mut client = zynq_dnn::coordinator::NetClient::connect(&addr)?;
    // the reply waits out the old version's drain — be generous
    client.set_timeout(Some(std::time::Duration::from_secs(120)))?;
    let summary = client.swap(model, path)?;
    println!("{summary}");
    for line in client.models()? {
        println!("{line}");
    }
    client.quit()?;
    Ok(())
}

fn sim(args: &Args) -> Result<()> {
    let name = args.get_or("network", "mnist4");
    let batch = args.get_usize("batch", 16)?;
    let prune = args.get_f64("prune", 0.9)?;
    let net = load_or_random(args, name)?;

    let acc = BatchAccelerator::zedboard(batch);
    let t = acc.timing_only(&net);
    println!(
        "batch design n={batch} (m={}): {} / sample, {} total, {} weight bytes",
        acc.m,
        zynq_dnn::util::fmt_time(t.per_sample()),
        zynq_dnn::util::fmt_time(t.total_seconds),
        t.total_weight_bytes()
    );
    for l in &t.layers {
        println!(
            "  layer {}: {}  ({} cycles, {} B, {})",
            l.layer,
            zynq_dnn::util::fmt_time(l.seconds),
            l.compute_cycles,
            l.weight_bytes,
            if l.memory_bound { "memory-bound" } else { "compute-bound" }
        );
    }

    let pruned = prune_qnetwork(&net, prune);
    let snet = SparseNetwork::encode(&pruned)?;
    let pt = PruningAccelerator::zedboard().timing_only(&snet);
    println!(
        "pruning design (q target {:.2}, achieved {:.3}): {} / sample, stream {} B",
        prune,
        snet.prune_factor(),
        zynq_dnn::util::fmt_time(pt.per_sample()),
        snet.stream_bytes(),
    );
    Ok(())
}

/// `profile`: compile one plan with per-layer profiling on, push `--runs`
/// seeded random batches through it, and print the per-layer table — the
/// runtime twin of the paper's Fig. 7 layer breakdown.  `--artifact`
/// profiles the compressed model's own kernels (calibrated threshold,
/// codebook layers intact); otherwise `--network`/`--weights` pick the
/// net and `--threshold` the kernel-selection policy.  `--backend sim`
/// swaps the measured host kernels for the simulated ZedBoard's modeled
/// DMA + compute breakdown (the same timing the `sim` serving backend
/// stamps on every reply).
fn profile(args: &Args) -> Result<()> {
    let batch = args.get_usize("batch", 25)?;
    let quick = bench::quick_mode();
    let runs = args.get_usize("runs", if quick { 8 } else { 64 })?;
    let threads = args.get_usize("threads", 1)?;
    if args.get_or("backend", "native") == "sim" {
        let (factory, name) = build_factory(args, "sim", batch)?;
        let report = BatchAccelerator::zedboard(batch.max(1)).timing_only(&factory.net);
        println!("{}", zynq_dnn::sim::engine::timing_table(&name, batch, &report));
        return Ok(());
    }
    let (factory, name) = build_factory(args, "native", batch)?;
    let s_in = factory.net.spec.inputs();

    let mut opts = PlanOptions::default().with_threads(threads).with_profile(true);
    if let Some(t) = factory.sparse_threshold {
        opts.sparse_threshold = t;
    }
    // an artifact's kernel choice is its own (calibrated at compression
    // time) unless an explicit --threshold asks for a recompile from the
    // reconstructed network
    let mut plan = match (&factory.artifact, factory.sparse_threshold) {
        (Some(model), None) => ExecPlan::compile_artifact_with(model, &opts)?,
        _ => ExecPlan::compile_q(&factory.net, &opts)?,
    };

    let mut rng = Xoshiro256::seed_from_u64(0xF16_7);
    for _ in 0..runs {
        let x = zynq_dnn::nn::quantize_matrix(&zynq_dnn::tensor::MatF::from_vec(
            batch,
            s_in,
            (0..batch * s_in)
                .map(|_| rng.uniform(-1.0, 1.0) as f32)
                .collect(),
        ));
        plan.run(&x)?;
    }
    let p = plan
        .profile()
        .expect("compiled with PlanOptions::profile on");
    println!(
        "{}",
        p.render(&format!(
            "{name} per-layer profile (batch {batch}, {runs} runs, {threads} thread(s))"
        ))
    );
    Ok(())
}

fn run_bench(args: &Args) -> Result<()> {
    let which = args.positionals.get(1).map(String::as_str).unwrap_or("all");
    let all = which == "all";
    let mut ran = false;
    // the serving benches also write their machine-readable twin next to
    // the repo root so dashboards can diff runs without scraping tables
    let emit = |name: &str, json: &str| -> Result<()> {
        let path = bench::write_json(name, json)
            .with_context(|| format!("writing BENCH_{name}.json"))?;
        eprintln!("wrote {}", path.display());
        Ok(())
    };
    if all || which == "table2" {
        println!("{}", bench::table2::render(&bench::table2::run()));
        ran = true;
    }
    if all || which == "table3" {
        println!("{}", bench::table3::render(&bench::table3::run()));
        ran = true;
    }
    if all || which == "table4" {
        println!("{}", bench::table4::render(&bench::table4::run()));
        ran = true;
    }
    if all || which == "fig7" {
        println!("{}", bench::fig7::render(&bench::fig7::run()));
        ran = true;
    }
    if all || which == "gops" {
        println!("{}", bench::gops::render(&bench::gops::run()));
        ran = true;
    }
    if all || which == "nopt" {
        println!("{}", bench::nopt::render(&bench::nopt::run()));
        ran = true;
    }
    if all || which == "combined" {
        println!("{}", bench::combined::render(&bench::combined::run()));
        ran = true;
    }
    if all || which == "ablation" {
        println!("{}", bench::ablation::render(&bench::ablation::run()));
        ran = true;
    }
    if all || which == "sparse" {
        let s = bench::sparse::run();
        println!("{}", bench::sparse::render(&s));
        emit("sparse", &bench::sparse::to_json(&s))?;
        ran = true;
    }
    if all || which == "calibrate" {
        println!("{}", bench::calibrate::render(&bench::calibrate::run()));
        ran = true;
    }
    if all || which == "compress" {
        let c = bench::compress::run()?;
        println!("{}", bench::compress::render(&c));
        emit("compress", &bench::compress::to_json(&c))?;
        // deterministic gate (no wall-clock dependence): the budget must
        // hold on every row and the artifact must round-trip bit-exact —
        // run by the CI "compress smoke" job
        if let Err(e) = bench::compress::check_shape(&c) {
            bail!("compress shape check failed: {e}");
        }
        ran = true;
    }
    if all || which == "slo" {
        let slo = bench::slo::run_with_backend(args.get_or("backend", "native"));
        println!("{}", bench::slo::render(&slo));
        emit("slo", &bench::slo::to_json(&slo))?;
        // the CI smoke job runs `bench slo --quick`: scheduler regressions
        // must fail the build, not just print a slower table
        if let Err(e) = bench::slo::check_shape(&slo) {
            if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
                eprintln!("slo shape check FAILED (ignored, ZDNN_SKIP_PERF=1): {e}");
            } else {
                bail!("slo shape check failed: {e}");
            }
        }
        ran = true;
    }
    if all || which == "net" {
        let n = bench::netbench::run();
        println!("{}", bench::netbench::render(&n));
        emit("net", &bench::netbench::to_json(&n))?;
        // wall-clock gates: pipelining (depth 16 > depth 1), v3 binary
        // wire economy (< 0.3x v2 text bytes, rps no worse), fan-in with
        // zero lost replies, and a leak-free churn soak
        if let Err(e) = bench::netbench::check_shape(&n) {
            if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
                eprintln!("net shape check FAILED (ignored, ZDNN_SKIP_PERF=1): {e}");
            } else {
                bail!("net shape check failed: {e}");
            }
        }
        ran = true;
    }
    if all || which == "obs" {
        let o = bench::obsbench::run();
        println!("{}", bench::obsbench::render(&o));
        emit("obs", &bench::obsbench::to_json(&o))?;
        // the PR 7 overhead gate: disabled tracing/profiling must stay
        // free; run by the CI "obs smoke" job
        if let Err(e) = bench::obsbench::check_shape(&o) {
            if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
                eprintln!("obs shape check FAILED (ignored, ZDNN_SKIP_PERF=1): {e}");
            } else {
                bail!("obs shape check failed: {e}");
            }
        }
        ran = true;
    }
    if all || which == "sim" {
        let s = bench::simserve::run();
        println!("{}", bench::simserve::render(&s));
        emit("sim", &bench::simserve::to_json(&s))?;
        // deterministic gate (modeled timing, golden outputs — no
        // wall-clock dependence): run unconditionally, CI "sim smoke" job
        if let Err(e) = bench::simserve::check_shape(&s) {
            bail!("sim shape check failed: {e}");
        }
        ran = true;
    }
    if all || which == "autoscale" {
        let a = bench::autoscale::run();
        println!("{}", bench::autoscale::render(&a));
        emit("autoscale", &bench::autoscale::to_json(&a))?;
        // wall-clock gates: scale-up under the step, steady tail within
        // 2x the static ceiling, park back to the floor, nothing lost
        if let Err(e) = bench::autoscale::check_shape(&a) {
            if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
                eprintln!("autoscale shape check FAILED (ignored, ZDNN_SKIP_PERF=1): {e}");
            } else {
                bail!("autoscale shape check failed: {e}");
            }
        }
        ran = true;
    }
    if all || which == "registry" {
        let r = bench::registry::run()?;
        println!("{}", bench::registry::render(&r));
        emit("registry", &bench::registry::to_json(&r))?;
        // functional gate (no wall-clock dependence): the hot swap under
        // load must lose nothing — run by the CI "registry smoke" job
        if let Err(e) = bench::registry::check_shape(&r) {
            bail!("registry shape check failed: {e}");
        }
        ran = true;
    }
    if !ran {
        bail!(
            "unknown bench {which:?} (table2|table3|table4|fig7|gops|nopt|combined|\
             ablation|sparse|calibrate|compress|slo|net|obs|registry|sim|autoscale|all)"
        );
    }
    Ok(())
}
