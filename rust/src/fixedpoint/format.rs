//! Generic Qm.n fixed-point formats (paper §6.4 / ref [30]: the accuracy
//! impact of different integer/fraction splits, and §4.1: the throughput
//! impact of the total width).  The production datapath is Q7.8; this
//! module parameterizes the format so the ablation bench can sweep both
//! axes on real trained networks.

use anyhow::{ensure, Result};

/// A signed fixed-point format: 1 sign bit + `int_bits` + `frac_bits`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    pub int_bits: u32,
    pub frac_bits: u32,
}

/// The paper's weight/activation format.
pub const Q7_8: QFormat = QFormat {
    int_bits: 7,
    frac_bits: 8,
};

impl QFormat {
    pub fn new(int_bits: u32, frac_bits: u32) -> Result<Self> {
        ensure!(
            int_bits + frac_bits + 1 <= 32 && frac_bits >= 1,
            "unsupported format Q{int_bits}.{frac_bits}"
        );
        Ok(Self {
            int_bits,
            frac_bits,
        })
    }

    /// Total stored bits (`b_weight` in §4.4).
    pub fn total_bits(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Representable rails as raw integers.
    pub fn max_raw(&self) -> i64 {
        (1i64 << (self.int_bits + self.frac_bits)) - 1
    }

    pub fn min_raw(&self) -> i64 {
        -(1i64 << (self.int_bits + self.frac_bits))
    }

    /// Quantize a real value (round half to even, saturate).
    pub fn quantize(&self, x: f64) -> i32 {
        let q = super::round_half_even(x * f64::from(1u32 << self.frac_bits));
        (q as i64).clamp(self.min_raw(), self.max_raw()) as i32
    }

    pub fn dequantize(&self, q: i32) -> f64 {
        f64::from(q) / f64::from(1u32 << self.frac_bits)
    }

    /// Quantization step (1 ulp) in real units.
    pub fn ulp(&self) -> f64 {
        1.0 / f64::from(1u32 << self.frac_bits)
    }

    /// Largest representable magnitude in real units.
    pub fn max_value(&self) -> f64 {
        self.dequantize(self.max_raw() as i32)
    }

    /// Accumulator format of a product of two values in this format
    /// (the DSP multiplier widens both fields).
    pub fn acc_format(&self) -> QFormat {
        QFormat {
            int_bits: 2 * self.int_bits + 1,
            frac_bits: 2 * self.frac_bits,
        }
    }

    /// Requantize an accumulator of `self.acc_format()` back to `self`
    /// (round-to-nearest via the overflow-free shift identity, saturate).
    pub fn requantize_acc(&self, acc: i64) -> i32 {
        let shift = self.frac_bits;
        let rounded = (acc >> shift) + ((acc >> (shift - 1)) & 1);
        rounded.clamp(self.min_raw(), self.max_raw()) as i32
    }
}

/// Round-trip quantization error of a weight matrix under a format:
/// max |w - deq(quant(w))| (the §6.4 accuracy driver).
pub fn matrix_quant_error(format: QFormat, weights: &[f32]) -> f64 {
    weights
        .iter()
        .map(|&w| {
            let q = format.quantize(f64::from(w));
            (f64::from(w) - format.dequantize(q)).abs()
        })
        .fold(0.0, f64::max)
}

/// Run an f32 network forward with all weights/activations quantized to an
/// arbitrary format (reference implementation for the format sweep — the
/// production Q7.8 path in `nn::forward` is the bit-exact twin for Q7.8).
pub fn forward_with_format(
    format: QFormat,
    spec: &crate::nn::spec::NetworkSpec,
    weights: &[crate::tensor::MatF],
    x: &crate::tensor::MatF,
) -> crate::tensor::MatI {
    use crate::tensor::MatI;
    let mut a = MatI {
        rows: x.rows,
        cols: x.cols,
        data: x.data.iter().map(|&v| format.quantize(f64::from(v))).collect(),
    };
    for (w, actfn) in weights.iter().zip(spec.activations.iter()) {
        let wq: Vec<i32> = w.data.iter().map(|&v| format.quantize(f64::from(v))).collect();
        let mut z = MatI::zeros(a.rows, w.rows);
        for n in 0..a.rows {
            for o in 0..w.rows {
                let mut acc = 0i64;
                let wr = &wq[o * w.cols..(o + 1) * w.cols];
                for (xa, wv) in a.row(n).iter().zip(wr.iter()) {
                    acc += i64::from(*xa) * i64::from(*wv);
                }
                // activation in real units on the widened accumulator
                let real = acc as f64 / (1u64 << (2 * format.frac_bits)) as f64;
                let out = actfn.apply_f32(real as f32);
                z.set(n, o, format.quantize(f64::from(out)));
            }
        }
        a = z;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q78_matches_production_quantizer() {
        for x in [-1.0, -0.25, 0.0, 0.3, 1.5, 127.996, -128.0, 200.0] {
            assert_eq!(Q7_8.quantize(x), crate::fixedpoint::quantize(x), "{x}");
        }
        assert_eq!(Q7_8.total_bits(), 16);
        assert_eq!(Q7_8.max_raw(), 32767);
        assert_eq!(Q7_8.min_raw(), -32768);
    }

    #[test]
    fn narrower_formats_coarser() {
        let q34 = QFormat::new(3, 4).unwrap(); // 8-bit
        let q78 = Q7_8;
        assert!(q34.ulp() > q78.ulp());
        assert!(q34.max_value() < q78.max_value());
        assert_eq!(q34.total_bits(), 8);
    }

    #[test]
    fn invalid_formats_rejected() {
        assert!(QFormat::new(20, 16).is_err());
        assert!(QFormat::new(7, 0).is_err());
    }

    #[test]
    fn acc_format_widens() {
        let acc = Q7_8.acc_format();
        assert_eq!(acc.int_bits, 15);
        assert_eq!(acc.frac_bits, 16);
    }

    #[test]
    fn requantize_acc_q78_matches_production() {
        for acc in [-1000i64, -129, -128, 0, 127, 128, 70000, i64::from(i32::MAX)] {
            let got = Q7_8.requantize_acc(acc);
            let want = crate::fixedpoint::requantize_acc(acc.clamp(
                i64::from(i32::MIN),
                i64::from(i32::MAX),
            ) as i32);
            assert_eq!(got, want, "acc={acc}");
        }
    }

    #[test]
    fn quant_error_bounded_by_half_ulp() {
        let ws: Vec<f32> = (-100..100).map(|i| i as f32 * 0.0133).collect();
        let err = matrix_quant_error(Q7_8, &ws);
        assert!(err <= Q7_8.ulp() / 2.0 + 1e-12, "{err}");
    }

    #[test]
    fn format_sweep_error_monotone_in_frac_bits() {
        let ws: Vec<f32> = (-50..50).map(|i| i as f32 * 0.017).collect();
        let mut last = f64::INFINITY;
        for frac in [4u32, 6, 8, 10] {
            let f = QFormat::new(5, frac).unwrap();
            let e = matrix_quant_error(f, &ws);
            assert!(e <= last + 1e-12, "frac={frac}");
            last = e;
        }
    }

    #[test]
    fn forward_with_q78_close_to_production_forward() {
        use crate::nn::spec::quickstart;
        use crate::tensor::MatF;
        use crate::util::rng::Xoshiro256;
        let spec = quickstart();
        let mut rng = Xoshiro256::seed_from_u64(2);
        let ws: Vec<MatF> = spec
            .weight_shapes()
            .iter()
            .map(|&(o, i)| {
                MatF::from_vec(
                    o,
                    i,
                    (0..o * i).map(|_| rng.normal_scaled(0.0, 0.1) as f32).collect(),
                )
            })
            .collect();
        let x = MatF::from_vec(
            2,
            64,
            (0..128).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let generic = forward_with_format(Q7_8, &spec, &ws, &x);
        let qnet = crate::nn::weights::NetworkWeights::new(spec, ws)
            .unwrap()
            .quantized();
        let xq = crate::nn::quantize_matrix(&x);
        let prod = crate::nn::forward::forward_q(&qnet, &xq).unwrap();
        // the generic path uses exact sigmoid, production uses PLAN: allow
        // a few Q7.8 ulps
        for (a, b) in generic.data.iter().zip(prod.data.iter()) {
            assert!((a - b).abs() <= 8, "{a} vs {b}");
        }
    }
}
