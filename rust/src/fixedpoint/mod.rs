//! Q7.8 fixed-point datapath (paper §5.3–5.4).
//!
//! The accelerator's number formats:
//! * **Q7.8** — 1 sign + 7 integer + 8 fraction bits for weights and
//!   activations (stored here in `i32` lanes to match the XLA int32
//!   artifacts; the value range is the i16 range).
//! * **Q15.16** — the 32-bit accumulator of a Q7.8 × Q7.8 MAC chain,
//!   wrapping two's-complement exactly like a DSP48 accumulator and XLA's
//!   int32 dot.
//!
//! Every function in this module is the bit-exact twin of
//! `python/compile/kernels/activations.py` / `ref.py`; integration tests
//! assert equality through the PJRT artifacts.

pub mod format;

/// Fraction bits of the Q7.8 activation/weight format.
pub const FRAC_BITS: u32 = 8;
/// Fraction bits of the Q15.16 accumulator.
pub const ACC_FRAC_BITS: u32 = 16;
/// 1.0 on the Q7.8 grid.
pub const Q78_ONE: i32 = 1 << FRAC_BITS;
/// Q7.8 rails (i16 range).
pub const Q78_MIN: i32 = -(1 << 15);
pub const Q78_MAX: i32 = (1 << 15) - 1;
/// Bits per stored weight (`b_weight` in the paper's §4.4 formulas).
pub const WEIGHT_BITS: u32 = 16;

/// Round half to even (numpy `rint` semantics, which the python compile
/// path uses when quantizing) — `f64::round` rounds half away from zero
/// and would disagree on exact .5 ties.
#[inline]
pub fn round_half_even(x: f64) -> f64 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 && (r as i64) % 2 != 0 {
        r - (x.signum())
    } else {
        r
    }
}

/// f32/f64 -> Q7.8 grid (round half to even, saturate), stored in i32.
#[inline]
pub fn quantize(x: f64) -> i32 {
    let q = round_half_even(x * f64::from(Q78_ONE));
    q.clamp(f64::from(Q78_MIN), f64::from(Q78_MAX)) as i32
}

/// Q7.8 -> real value.
#[inline]
pub fn dequantize(q: i32) -> f64 {
    f64::from(q) / f64::from(Q78_ONE)
}

/// Quantize a slice.
pub fn quantize_slice(xs: &[f32]) -> Vec<i32> {
    xs.iter().map(|&x| quantize(f64::from(x))).collect()
}

/// Dequantize a slice.
pub fn dequantize_slice(qs: &[i32]) -> Vec<f32> {
    qs.iter().map(|&q| dequantize(q) as f32).collect()
}

/// One MAC step on the wrapping 32-bit accumulator: `acc + w*a` where both
/// operands are Q7.8.  This is the DSP-slice semantics (and XLA's int32
/// dot), NOT saturating.
#[inline(always)]
pub fn mac(acc: i32, w: i32, a: i32) -> i32 {
    acc.wrapping_add(w.wrapping_mul(a))
}

/// Q15.16 accumulator -> Q7.8, round-to-nearest (half away from zero via
/// the +bias formulation), saturating.  Overflow-free identity:
/// `(acc + 128) >> 8 == (acc >> 8) + ((acc >> 7) & 1)`.
#[inline(always)]
pub fn requantize_acc(acc: i32) -> i32 {
    let shift = ACC_FRAC_BITS - FRAC_BITS;
    let rounded = (acc >> shift) + ((acc >> (shift - 1)) & 1);
    rounded.clamp(Q78_MIN, Q78_MAX)
}

/// ReLU on the accumulator, requantized to Q7.8.
#[inline(always)]
pub fn relu_acc(acc: i32) -> i32 {
    requantize_acc(acc.max(0))
}

// PLAN segment breakpoints on the Q15.16 accumulator.
const PLAN_B5: i64 = 5 << ACC_FRAC_BITS;
const PLAN_B2375: i64 = (2 << ACC_FRAC_BITS) + (3 << (ACC_FRAC_BITS - 3));
const PLAN_B1: i64 = 1 << ACC_FRAC_BITS;

/// PLAN sigmoid (Amin et al. 1997) on the Q15.16 accumulator -> Q7.8 in
/// [0, 256].  Shift/add only — the exact wiring of the paper's activation
/// unit (§5.4) and of `activations.plan_sigmoid_acc`.
#[inline(always)]
pub fn plan_sigmoid_acc(acc: i32) -> i32 {
    let mag = i64::from(acc).abs();
    let y = if mag >= PLAN_B5 {
        i64::from(Q78_ONE)
    } else if mag >= PLAN_B2375 {
        (mag >> 13) + 216
    } else if mag >= PLAN_B1 {
        (mag >> 11) + 160
    } else {
        (mag >> 10) + 128
    };
    let y = if acc < 0 { i64::from(Q78_ONE) - y } else { y };
    y.clamp(0, i64::from(Q78_ONE)) as i32
}

/// No activation: plain requantization (output/logit layers).
#[inline(always)]
pub fn identity_acc(acc: i32) -> i32 {
    requantize_acc(acc)
}

/// Exact real sigmoid, for PLAN error measurements.
pub fn sigmoid_exact(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// Maximum |PLAN − sigmoid| over a dense sweep (Amin et al. cite ~1.89 %;
/// our Q7.8 output adds quantization, bound asserted < 0.022 in tests).
pub fn plan_max_error() -> f64 {
    let mut max_err: f64 = 0.0;
    let n = 200_001;
    for i in 0..n {
        let x = -8.0 + 16.0 * (i as f64) / ((n - 1) as f64);
        let acc = round_half_even(x * (1i64 << ACC_FRAC_BITS) as f64) as i64;
        let acc32 = acc.clamp(i64::from(i32::MIN), i64::from(i32::MAX)) as i32;
        let y = f64::from(plan_sigmoid_acc(acc32)) / f64::from(Q78_ONE);
        max_err = max_err.max((y - sigmoid_exact(x)).abs());
    }
    max_err
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn quantize_round_half_even_matches_numpy_rint() {
        // x*256 = 0.5 -> 0 (even), 1.5 -> 2, 2.5 -> 2, -0.5 -> 0, -1.5 -> -2
        assert_eq!(quantize(0.5 / 256.0), 0);
        assert_eq!(quantize(1.5 / 256.0), 2);
        assert_eq!(quantize(2.5 / 256.0), 2);
        assert_eq!(quantize(-0.5 / 256.0), 0);
        assert_eq!(quantize(-1.5 / 256.0), -2);
    }

    #[test]
    fn quantize_saturates() {
        assert_eq!(quantize(1e9), Q78_MAX);
        assert_eq!(quantize(-1e9), Q78_MIN);
        assert_eq!(quantize(127.99609375), Q78_MAX); // 32767/256
    }

    #[test]
    fn quantize_dequantize_roundtrip_on_grid() {
        for q in [-32768, -255, -1, 0, 1, 255, 32767] {
            assert_eq!(quantize(dequantize(q)), q);
        }
    }

    #[test]
    fn requantize_known_points() {
        assert_eq!(requantize_acc(0), 0);
        assert_eq!(requantize_acc(127), 0);
        assert_eq!(requantize_acc(128), 1);
        assert_eq!(requantize_acc(-128), 0);
        assert_eq!(requantize_acc(-129), -1);
        assert_eq!(requantize_acc(i32::MAX), Q78_MAX);
        assert_eq!(requantize_acc(i32::MIN), Q78_MIN);
    }

    #[test]
    fn requantize_identity_matches_bias_formula() {
        prop_check(2000, |g| {
            let acc = g.i32_full();
            let want = ((i64::from(acc) + 128) >> 8).clamp(-32768, 32767) as i32;
            requantize_acc(acc) == want
        });
    }

    #[test]
    fn plan_sigmoid_known_points() {
        let q16 = |x: f64| (x * 65536.0).round() as i32;
        assert_eq!(plan_sigmoid_acc(q16(0.0)), 128);
        assert_eq!(plan_sigmoid_acc(q16(10.0)), 256);
        assert_eq!(plan_sigmoid_acc(q16(-10.0)), 0);
        assert_eq!(plan_sigmoid_acc(q16(1.0)), 192);
        assert_eq!(plan_sigmoid_acc(q16(-1.0)), 64);
    }

    #[test]
    fn plan_sigmoid_symmetry_and_monotone() {
        prop_check(2000, |g| {
            let x = g.i32_full();
            let y = g.i32_full();
            let (lo, hi) = (x.min(y), x.max(y));
            let sym = x != i32::MIN || plan_sigmoid_acc(x) == 0;
            let sym = sym
                && (x == i32::MIN
                    || plan_sigmoid_acc(x) + plan_sigmoid_acc(-x) == Q78_ONE);
            sym && plan_sigmoid_acc(lo) <= plan_sigmoid_acc(hi)
        });
    }

    #[test]
    fn plan_sigmoid_int_min_is_zero() {
        assert_eq!(plan_sigmoid_acc(i32::MIN), 0);
    }

    #[test]
    fn plan_error_bound() {
        assert!(plan_max_error() < 0.022);
    }

    #[test]
    fn relu_clamps_negative_only() {
        assert_eq!(relu_acc(-(1 << 20)), 0);
        assert_eq!(relu_acc(1 << 20), (1 << 20) >> 8);
        assert_eq!(relu_acc(i32::MIN), 0);
    }

    #[test]
    fn mac_wraps_like_hardware() {
        // 32767 * 32767 accumulated twice: wraps, does not saturate
        let mut acc = 0i32;
        for _ in 0..4 {
            acc = mac(acc, 32767, 32767);
        }
        let want = (4i64 * 32767 * 32767) as i64;
        assert_eq!(acc, (want & 0xFFFF_FFFF) as u32 as i32);
    }

    #[test]
    fn slice_roundtrip() {
        let xs = [0.5f32, -0.25, 1.0, -128.0, 127.0];
        let q = quantize_slice(&xs);
        let back = dequantize_slice(&q);
        for (x, b) in xs.iter().zip(back.iter()) {
            assert!((x - b).abs() <= 0.5 / 256.0 + 1e-6, "{x} vs {b}");
        }
    }
}
