//! PJRT runtime (the AOT bridge): loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them on the PJRT CPU client, and
//! executes them from the serving hot path.  Python never runs here.
//!
//! Thread model: the `xla` crate's handles wrap raw pointers (not `Send`),
//! so one [`Runtime`] lives on one engine thread; the coordinator feeds it
//! batches through channels (see `coordinator::server`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::config::json::{self, Json};
use crate::nn::spec::{Activation, NetworkSpec};
use crate::tensor::MatI;

/// One artifact in the manifest: a lowered (network, batch) variant.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub network: String,
    pub architecture: Vec<usize>,
    pub activations: Vec<String>,
    pub batch: usize,
    pub file: String,
    pub input_shape: (usize, usize),
    pub weight_shapes: Vec<(usize, usize)>,
    pub output_shape: (usize, usize),
    pub num_parameters: usize,
}

impl ArtifactEntry {
    fn from_json(j: &Json) -> Result<Self> {
        let shape2 = |v: &Json| -> Result<(usize, usize)> {
            let s = v.as_usize_vec()?;
            ensure!(s.len() == 2, "expected rank-2 shape, got {s:?}");
            Ok((s[0], s[1]))
        };
        Ok(Self {
            network: j.req("network")?.as_str()?.to_string(),
            architecture: j.req("architecture")?.as_usize_vec()?,
            activations: j.req("activations")?.as_str_vec()?,
            batch: j.req("batch")?.as_usize()?,
            file: j.req("file")?.as_str()?.to_string(),
            input_shape: shape2(j.req("input_shape")?)?,
            weight_shapes: j
                .req("weight_shapes")?
                .as_arr()?
                .iter()
                .map(shape2)
                .collect::<Result<_>>()?,
            output_shape: shape2(j.req("output_shape")?)?,
            num_parameters: j.req("num_parameters")?.as_usize()?,
        })
    }

    /// The rust-side spec equivalent (cross-checked against nn::spec).
    pub fn spec(&self) -> Result<NetworkSpec> {
        let acts = self
            .activations
            .iter()
            .map(|a| Activation::from_name(a))
            .collect::<Result<Vec<_>>>()?;
        NetworkSpec::new(&self.network, &self.architecture).with_activations(&acts)
    }
}

/// The parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub entries: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let j = json::parse(&text)?;
        let version = j.req("version")?.as_usize()?;
        ensure!(version == 2, "manifest version {version} unsupported (expected 2)");
        let entries = j
            .req("entries")?
            .as_arr()?
            .iter()
            .map(ArtifactEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        ensure!(!entries.is_empty(), "manifest has no entries");
        Ok(Self {
            version,
            entries,
            dir: dir.to_path_buf(),
        })
    }

    pub fn find(&self, network: &str, batch: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.network == network && e.batch == batch)
    }

    /// Batch sizes available for a network (sorted).
    pub fn batches_for(&self, network: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.network == network)
            .map(|e| e.batch)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn networks(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.iter().map(|e| e.network.clone()).collect();
        v.sort();
        v.dedup();
        v
    }
}

/// A compiled (network, batch) executable.
pub struct CompiledModel {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

/// Network weights pinned as device buffers — uploaded once, reused across
/// executions.  This is the hot-path optimization recorded in
/// EXPERIMENTS.md §Perf: marshalling megabytes of weight literals per
/// `execute` dominated the serving latency by >10×.
pub struct BoundWeights {
    buffers: Vec<xla::PjRtBuffer>,
}

impl CompiledModel {
    /// Execute one batch.  `x` is (batch × s_0) Q7.8/i32; `weights` are the
    /// network's quantized matrices (passed as runtime parameters, so the
    /// same executable serves any trained/pruned weight set).
    pub fn execute(&self, x: &MatI, weights: &[MatI]) -> Result<MatI> {
        let (bn, bs) = self.entry.input_shape;
        ensure!(
            x.shape() == (bn, bs),
            "input shape {:?} != artifact {:?}",
            x.shape(),
            (bn, bs)
        );
        ensure!(
            weights.len() == self.entry.weight_shapes.len(),
            "expected {} weight matrices",
            self.entry.weight_shapes.len()
        );
        let mut literals = Vec::with_capacity(1 + weights.len());
        literals.push(
            xla::Literal::vec1(&x.data).reshape(&[x.rows as i64, x.cols as i64])?,
        );
        for (w, &(o, i)) in weights.iter().zip(self.entry.weight_shapes.iter()) {
            ensure!(w.shape() == (o, i), "weight shape {:?} != {:?}", w.shape(), (o, i));
            literals.push(xla::Literal::vec1(&w.data).reshape(&[o as i64, i as i64])?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        let data = result.to_vec::<i32>()?;
        let (on, oc) = self.entry.output_shape;
        ensure!(data.len() == on * oc, "output length {} != {}", data.len(), on * oc);
        Ok(MatI::from_vec(on, oc, data))
    }

    /// Upload the weight matrices to device buffers once.
    pub fn bind_weights(&self, weights: &[MatI]) -> Result<BoundWeights> {
        ensure!(
            weights.len() == self.entry.weight_shapes.len(),
            "expected {} weight matrices",
            self.entry.weight_shapes.len()
        );
        let mut buffers = Vec::with_capacity(weights.len());
        for (w, &(o, i)) in weights.iter().zip(self.entry.weight_shapes.iter()) {
            ensure!(w.shape() == (o, i), "weight shape {:?} != {:?}", w.shape(), (o, i));
            buffers.push(
                self.client
                    .buffer_from_host_buffer::<i32>(&w.data, &[o, i], None)?,
            );
        }
        Ok(BoundWeights { buffers })
    }

    /// Execute against pre-bound weights: only the activation batch crosses
    /// the host/device boundary per call.
    pub fn execute_bound(&self, x: &MatI, weights: &BoundWeights) -> Result<MatI> {
        let (bn, bs) = self.entry.input_shape;
        ensure!(
            x.shape() == (bn, bs),
            "input shape {:?} != artifact {:?}",
            x.shape(),
            (bn, bs)
        );
        let x_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&x.data, &[x.rows, x.cols], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + weights.buffers.len());
        args.push(&x_buf);
        args.extend(weights.buffers.iter());
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        let data = result.to_vec::<i32>()?;
        let (on, oc) = self.entry.output_shape;
        ensure!(data.len() == on * oc, "output length {} != {}", data.len(), on * oc);
        Ok(MatI::from_vec(on, oc, data))
    }
}

/// The PJRT runtime: CPU client + compile cache over the manifest.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<(String, usize), std::rc::Rc<CompiledModel>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            manifest,
            client,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (cached) a (network, batch) artifact.
    pub fn load(&mut self, network: &str, batch: usize) -> Result<std::rc::Rc<CompiledModel>> {
        let key = (network.to_string(), batch);
        if let Some(m) = self.cache.get(&key) {
            return Ok(m.clone());
        }
        let Some(entry) = self.manifest.find(network, batch).cloned() else {
            bail!(
                "no artifact for {network} at batch {batch}; available: {:?}",
                self.manifest.batches_for(network)
            );
        };
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", entry.file))?;
        let model = std::rc::Rc::new(CompiledModel {
            entry,
            exe,
            client: self.client.clone(),
        });
        self.cache.insert(key, model.clone());
        Ok(model)
    }
}

/// Locate the artifacts directory: `$ZDNN_ARTIFACTS`, else `./artifacts`
/// relative to the workspace root.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("ZDNN_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = default_artifacts_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn manifest_loads_and_indexes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.networks().contains(&"quickstart".to_string()));
        let e = m.find("quickstart", 1).expect("quickstart b1");
        assert_eq!(e.architecture, vec![64, 48, 10]);
        assert_eq!(e.weight_shapes, vec![(48, 64), (10, 48)]);
        let spec = e.spec().unwrap();
        assert_eq!(spec.num_parameters(), e.num_parameters);
        assert!(m.find("quickstart", 999).is_none());
    }

    #[test]
    fn manifest_missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent-zdnn")).unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("make artifacts"), "{chain}");
    }
}
