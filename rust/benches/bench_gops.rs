//! `cargo bench --bench bench_gops` — regenerates the paper's gops artefact
//! and fails (exit 1) if its qualitative shape check does not hold.
fn main() {
    let t0 = std::time::Instant::now();
    let r = zynq_dnn::bench::gops::run();
    println!("{}", zynq_dnn::bench::gops::render(&r));
    if let Err(e) = zynq_dnn::bench::gops::check_shape(&r) {
        eprintln!("SHAPE CHECK FAILED: {e}");
        std::process::exit(1);
    }
    println!("shape check OK ({:.2}s)", t0.elapsed().as_secs_f64());
}
