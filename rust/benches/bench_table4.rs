//! `cargo bench --bench bench_table4` — regenerates the paper's table4 artefact
//! and fails (exit 1) if its qualitative shape check does not hold.
fn main() {
    let t0 = std::time::Instant::now();
    let r = zynq_dnn::bench::table4::run();
    println!("{}", zynq_dnn::bench::table4::render(&r));
    if let Err(e) = zynq_dnn::bench::table4::check_shape(&r) {
        eprintln!("SHAPE CHECK FAILED: {e}");
        std::process::exit(1);
    }
    println!("shape check OK ({:.2}s)", t0.elapsed().as_secs_f64());
}
