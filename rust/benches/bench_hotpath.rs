//! `cargo bench --bench bench_hotpath` — microbenchmarks of the hot paths
//! the perf pass iterates on (EXPERIMENTS.md §Perf):
//!
//! * i32 wrapping GEMM (naive / blocked / parallel) on paper-sized layers
//! * f32 blocked GEMM (software baseline)
//! * batch-design simulator (functional and timing-only)
//! * pruning stream encode + decode
//! * serving round-trip overhead (native backend, batch 8)

use std::time::Duration;

use zynq_dnn::bench::random_qnet;
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::{EngineFactory, Server, SubmitOptions, SubmitTarget};
use zynq_dnn::nn::spec::{har_6, mnist_4, quickstart};
use zynq_dnn::sim::batch::BatchAccelerator;
use zynq_dnn::sim::pruning::{prune_qnetwork, SparseNetwork};
use zynq_dnn::tensor::{gemm_f32, gemm_i32, gemm_i32_naive, gemm_i32_parallel, MatF, MatI};
use zynq_dnn::util::rng::Xoshiro256;
use zynq_dnn::util::threadpool::ThreadPool;
use zynq_dnn::util::{bench_loop, fmt_time};

fn report(name: &str, mean: f64, work_items: f64, unit: &str) {
    println!(
        "{name:<44} {:>12}   {:>12.2} M{unit}/s",
        fmt_time(mean),
        work_items / mean / 1e6
    );
}

fn main() {
    let quick = std::env::var("ZDNN_QUICK").map(|v| v == "1").unwrap_or(false);
    let iters = if quick { 3 } else { 12 };
    println!("hot-path microbenchmarks (iters={iters})\n");

    // ---- GEMM: the 2000×1500 HAR-6 layer, batch 16 ----
    let (n, k, o) = (16usize, 1500usize, 2000usize);
    let mut rng = Xoshiro256::seed_from_u64(1);
    let x = MatI::from_vec(n, k, (0..n * k).map(|_| rng.below(65536) as i32 - 32768).collect());
    let w = MatI::from_vec(o, k, (0..o * k).map(|_| rng.below(65536) as i32 - 32768).collect());
    let macs = (n * k * o) as f64;

    let mut out = MatI::zeros(n, o);
    let (t_naive, _) = bench_loop(1, iters.min(4), || gemm_i32_naive(&x, &w, &mut out));
    report("gemm_i32 naive (16x1500 @ 2000x1500)", t_naive, macs, "MAC");

    let (t_blocked, _) = bench_loop(1, iters, || gemm_i32(&x, &w, &mut out));
    report("gemm_i32 blocked", t_blocked, macs, "MAC");

    let pool = ThreadPool::host();
    let (t_par, _) = bench_loop(1, iters, || gemm_i32_parallel(&pool, &x, &w, &mut out));
    report(
        &format!("gemm_i32 parallel ({} threads)", pool.threads()),
        t_par,
        macs,
        "MAC",
    );
    println!(
        "  blocked speedup {:.2}x, parallel {:.2}x\n",
        t_naive / t_blocked,
        t_naive / t_par
    );

    let xf = MatF::from_vec(n, k, (0..n * k).map(|_| 0.01f32).collect());
    let wf = MatF::from_vec(o, k, (0..o * k).map(|_| 0.01f32).collect());
    let mut outf = MatF::zeros(n, o);
    let (t_f32, _) = bench_loop(1, iters, || gemm_f32(&xf, &wf, &mut outf));
    report("gemm_f32 blocked (software baseline)", t_f32, 2.0 * macs, "FLOP");
    println!();

    // ---- simulator throughput ----
    let net4 = random_qnet(&mnist_4(), 2);
    let acc = BatchAccelerator::zedboard(16);
    let (t_timing, _) = bench_loop(1, iters * 10, || acc.timing_only(&net4));
    report("sim batch-16 timing-only (mnist4)", t_timing, 1.0, "run");

    let xin = MatI::from_vec(16, 784, vec![64; 16 * 784]);
    let (t_func, _) = bench_loop(1, iters.min(6), || acc.run(&net4, &xin).unwrap());
    let sim_macs = (16 * 1_275_200) as f64;
    report("sim batch-16 functional (mnist4)", t_func, sim_macs, "MAC");
    println!();

    // ---- sparse stream ----
    let net6 = prune_qnetwork(&random_qnet(&har_6(), 3), 0.94);
    let (t_enc, _) = bench_loop(1, iters.min(6), || SparseNetwork::encode(&net6).unwrap());
    report("sparse encode (har6 @ q=0.94)", t_enc, 5_473_800.0, "weight");
    let snet = SparseNetwork::encode(&net6).unwrap();
    let (t_dec, _) = bench_loop(1, iters.min(6), || {
        zynq_dnn::sparse::decode_matrix(&snet.layers[0])
    });
    report("sparse decode layer 0 (2000x561)", t_dec, (2000 * 561) as f64, "weight");
    println!();

    // ---- serving round-trip overhead ----
    let qnet = random_qnet(&quickstart(), 4);
    let server = Server::start(
        &ServerConfig {
            batch: 8,
            batch_deadline_us: 100,
            ..Default::default()
        },
        EngineFactory {
            backend: "native".into(),
            batch: 8,
            net: qnet,
            artifacts_dir: zynq_dnn::runtime::default_artifacts_dir(),
            native_threads: 1,
            sparse_threshold: None,
            artifact: None,
        },
    )
    .unwrap();
    let reqs = if quick { 64 } else { 512 };
    let input: Vec<i32> = vec![32; 64];
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = (0..reqs)
        .map(|_| server.submit(input.clone(), SubmitOptions::default()).unwrap())
        .collect();
    for mut ticket in tickets {
        ticket.wait_timeout(Duration::from_secs(30)).unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics.snapshot();
    println!(
        "serve round-trip: {reqs} reqs in {} -> {:.0} req/s, mean latency {}, occupancy {:.2}",
        fmt_time(wall),
        reqs as f64 / wall,
        fmt_time(snap.mean_latency_s),
        snap.occupancy
    );
    server.shutdown().unwrap();
}
