//! `cargo bench --bench bench_obs` — observability overhead gate: the same
//! sparse plan with per-layer profiling off vs on (bit-equality asserted)
//! and the 2-worker pool with request tracing off vs on.  Exits 1 if the
//! disabled paths show measurable overhead or enabled profiling exceeds
//! its budget; set `ZDNN_SKIP_PERF=1` to downgrade to a warning.
fn main() {
    let t0 = std::time::Instant::now();
    let r = zynq_dnn::bench::obsbench::run();
    println!("{}", zynq_dnn::bench::obsbench::render(&r));
    if let Err(e) = zynq_dnn::bench::obsbench::check_shape(&r) {
        if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
            eprintln!("SHAPE CHECK FAILED (ignored, ZDNN_SKIP_PERF=1): {e}");
        } else {
            eprintln!("SHAPE CHECK FAILED: {e}");
            std::process::exit(1);
        }
    } else {
        println!("shape check OK ({:.2}s)", t0.elapsed().as_secs_f64());
    }
}
