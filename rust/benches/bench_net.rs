//! `cargo bench --bench bench_net` — wire benchmark over TCP loopback:
//! protocol generations {v2 text, v3 binary-i16} × pipeline depth
//! {1, 4, 16, 64} × client connections {1, 4} against the 4-worker
//! sharded pool, plus a 256-connection fan-in and a connection-churn
//! soak.  Exits 1 if a shape gate fails: depth 16 must beat depth 1 on
//! one connection, v3 must spend < 0.3× the wire bytes of v2 at rps no
//! worse, the fan-in must lose zero replies, and the soak must leak
//! neither fds nor threads.  `ZDNN_SKIP_PERF=1` downgrades gate
//! failures to warnings (contended runners).
fn main() {
    let t0 = std::time::Instant::now();
    let r = zynq_dnn::bench::netbench::run();
    println!("{}", zynq_dnn::bench::netbench::render(&r));
    if let Err(e) = zynq_dnn::bench::netbench::check_shape(&r) {
        if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
            eprintln!("SHAPE CHECK FAILED (ignored, ZDNN_SKIP_PERF=1): {e}");
        } else {
            eprintln!("SHAPE CHECK FAILED: {e}");
            std::process::exit(1);
        }
    }
    println!("shape check OK ({:.2}s)", t0.elapsed().as_secs_f64());
}
