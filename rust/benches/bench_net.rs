//! `cargo bench --bench bench_net` — wire-pipelining sweep over TCP
//! loopback: pipeline depth {1, 4, 16, 64} × client connections {1, 4}
//! against the 4-worker sharded pool.  Exits 1 if a single pipelined
//! connection at depth 16 fails to beat the same connection at depth 1
//! (the v1 lockstep bound protocol v2 removes).
fn main() {
    let t0 = std::time::Instant::now();
    let r = zynq_dnn::bench::netbench::run();
    println!("{}", zynq_dnn::bench::netbench::render(&r));
    if let Err(e) = zynq_dnn::bench::netbench::check_shape(&r) {
        eprintln!("SHAPE CHECK FAILED: {e}");
        std::process::exit(1);
    }
    println!("shape check OK ({:.2}s)", t0.elapsed().as_secs_f64());
}
