//! `cargo bench --bench bench_slo` — open-loop serving SLO sweep on the
//! sharded pool: batches {1, 25, 57} × workers {1, 2, 4} on the HAR-sized
//! net, plus a 1-worker priority-vs-FIFO head-to-head.  Exits 1 if 4
//! workers fail to beat 1 worker at any batch, or if the two-level queue
//! fails to improve interactive p99 over the FIFO baseline.
fn main() {
    let t0 = std::time::Instant::now();
    let r = zynq_dnn::bench::slo::run();
    println!("{}", zynq_dnn::bench::slo::render(&r));
    if let Err(e) = zynq_dnn::bench::slo::check_shape(&r) {
        eprintln!("SHAPE CHECK FAILED: {e}");
        std::process::exit(1);
    }
    println!("shape check OK ({:.2}s)", t0.elapsed().as_secs_f64());
}
