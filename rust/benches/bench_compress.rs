//! `cargo bench --bench bench_compress` — the compression pipeline end to
//! end: train a small net, sweep per-layer sensitivity, run the
//! accuracy-budgeted search over three budgets, round-trip each `.rpz`
//! artifact through disk, and time dense vs compressed serving plans.
//! Exits 1 if any budget is violated or an artifact fails to round-trip
//! bit-exact.
fn main() {
    let t0 = std::time::Instant::now();
    let b = match zynq_dnn::bench::compress::run() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("BENCH FAILED: {e:#}");
            std::process::exit(1);
        }
    };
    println!("{}", zynq_dnn::bench::compress::render(&b));
    if let Err(e) = zynq_dnn::bench::compress::check_shape(&b) {
        eprintln!("SHAPE CHECK FAILED: {e}");
        std::process::exit(1);
    }
    println!("shape check OK ({:.2}s)", t0.elapsed().as_secs_f64());
}
