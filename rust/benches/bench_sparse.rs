//! `cargo bench --bench bench_sparse` — dense vs sparse ExecPlan execution
//! across prune factors 0.5–0.95 at serving batches {1, 25, 57} on the
//! HAR-sized net, with bit-equality asserted on every configuration.
//! Exits 1 if sparse does not beat dense at prune factor >= 0.9.
fn main() {
    let t0 = std::time::Instant::now();
    let r = zynq_dnn::bench::sparse::run();
    println!("{}", zynq_dnn::bench::sparse::render(&r));
    if let Err(e) = zynq_dnn::bench::sparse::check_shape(&r) {
        eprintln!("SHAPE CHECK FAILED: {e}");
        std::process::exit(1);
    }
    println!("shape check OK ({:.2}s)", t0.elapsed().as_secs_f64());
}
