//! `cargo bench --bench bench_calibrate` — dense/CSR kernel crossover
//! calibration on the HAR-sized net; prints the measured pruning factor at
//! which the sparse plan starts winning (feed it to the CLI as
//! `--threshold`).  Exits 1 if sparse fails to win at the heaviest
//! pruning or the speedup does not grow with the pruning factor.
fn main() {
    let t0 = std::time::Instant::now();
    let r = zynq_dnn::bench::calibrate::run();
    println!("{}", zynq_dnn::bench::calibrate::render(&r));
    if let Err(e) = zynq_dnn::bench::calibrate::check_shape(&r) {
        eprintln!("SHAPE CHECK FAILED: {e}");
        std::process::exit(1);
    }
    println!("shape check OK ({:.2}s)", t0.elapsed().as_secs_f64());
}
