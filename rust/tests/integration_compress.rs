//! Integration: the compression pipeline end to end — artifact round-trips
//! are bit-exact through the execution plans, the budgeted search honours
//! its accuracy budget on random networks, and a compressed `.rpz` serves
//! through the sharded pool with its embedded calibration (no `--threshold`).

use std::path::PathBuf;
use std::time::Duration;

use zynq_dnn::bench::random_qnet;
use zynq_dnn::compress::{
    self, accuracy_q, codebook_quantize_matrix, load_artifact, save_artifact, ArtifactEncoding,
    CompressedModel, EvalSet, SearchConfig,
};
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::{EngineFactory, SubmitOptions, SubmitTarget};
use zynq_dnn::exec::{ExecPlan, KernelKind, PlanOptions};
use zynq_dnn::nn::forward_q;
use zynq_dnn::nn::quantize_matrix;
use zynq_dnn::nn::spec::{quickstart, NetworkSpec};
use zynq_dnn::serve::{Priority, ServePool};
use zynq_dnn::tensor::{
    column_nonzero_mask, spmm_i32, spmm_i32_opt, CsrCodebookMatI, CsrMatI, MatF, MatI,
};
use zynq_dnn::util::prop::prop_check;
use zynq_dnn::util::rng::Xoshiro256;

fn tmp_dir() -> PathBuf {
    let dir = std::env::temp_dir().join("zdnn_itest_rpz");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn rand_x(n: usize, cols: usize, seed: u64) -> MatI {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    quantize_matrix(&MatF::from_vec(
        n,
        cols,
        (0..n * cols).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
    ))
}

fn rand_eval(n: usize, features: usize, classes: usize, seed: u64) -> EvalSet {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let x = rand_x(n, features, seed ^ 0x1234);
    EvalSet {
        x,
        y: (0..n).map(|_| rng.index(classes)).collect(),
    }
}

/// ISSUE property: save → load → `ExecPlan` output bit-equal to the
/// in-memory pruned network, across random architectures, prune levels,
/// and thresholds (i.e. across dense/CSR blob mixes).
#[test]
fn prop_artifact_roundtrip_bit_exact_through_plans() {
    let dir = tmp_dir();
    let mut case = 0u64;
    prop_check(15, |g| {
        case += 1;
        let depth = g.usize(2..5);
        let sizes: Vec<usize> = (0..depth).map(|_| g.usize(1..20)).collect();
        let spec = NetworkSpec::new("prop", &sizes);
        let seed = g.u64(0..=u64::MAX / 2);
        let q = g.f64(0.0, 1.0);
        let threshold = g.f64(0.0, 1.2);
        let mut net = compress::prune_qnetwork(&random_qnet(&spec, seed), q);
        let encoding = match g.usize(0..3) {
            0 => ArtifactEncoding::Raw,
            1 => ArtifactEncoding::Delta,
            _ => ArtifactEncoding::Codebook,
        };
        if encoding == ArtifactEncoding::Codebook {
            // weight-share first so the codebook storage path is exercised
            // losslessly (what the search's codebook rung produces)
            for w in net.weights.iter_mut() {
                *w = codebook_quantize_matrix(w);
            }
        }
        let model =
            CompressedModel::from_network_encoded(&net, threshold, encoding, 0.0, 1.0, 1.0)
                .unwrap();
        let path = dir.join(format!("prop_{case}.rpz"));
        save_artifact(&path, &model).unwrap();
        let back = load_artifact(&path).unwrap();
        let mut from_artifact = ExecPlan::compile_artifact(&back, 1).unwrap();
        let mut from_memory = ExecPlan::compile_q(
            &net,
            &PlanOptions {
                sparse_threshold: threshold,
                ..PlanOptions::default()
            },
        )
        .unwrap();
        let x = rand_x(g.usize(1..6), sizes[0], seed ^ 0xF);
        from_artifact.run(&x).unwrap().data == from_memory.run(&x).unwrap().data
    });
}

/// ISSUE property: the budgeted search never exceeds its accuracy budget
/// on seeded random networks — re-measured independently, not read off
/// the outcome struct.
#[test]
fn prop_budgeted_search_never_exceeds_budget() {
    prop_check(10, |g| {
        let depth = g.usize(2..4);
        let sizes: Vec<usize> = (0..depth).map(|_| g.usize(2..16)).collect();
        let spec = NetworkSpec::new("prop", &sizes);
        let net = random_qnet(&spec, g.u64(0..=u64::MAX / 2));
        let eval = rand_eval(
            g.usize(10..40),
            sizes[0],
            *sizes.last().unwrap(),
            g.u64(0..=u64::MAX / 2),
        );
        let ladder = vec![0.5, 0.8, 0.95];
        let report = compress::sweep(&net, &eval, &ladder).unwrap();
        let budget = g.f64(0.0, 0.2);
        let outcome = compress::search(
            &net,
            &eval,
            &report,
            &SearchConfig {
                budget,
                ladder,
                encoding: if g.bool(0.5) {
                    ArtifactEncoding::Codebook
                } else {
                    ArtifactEncoding::Delta
                },
            },
        )
        .unwrap();
        let baseline = accuracy_q(&net, &eval).unwrap();
        let measured = accuracy_q(&outcome.network, &eval).unwrap();
        baseline - measured <= budget + 1e-9
            && (outcome.compressed_accuracy - measured).abs() < 1e-12
    });
}

/// Acceptance path: a compressed artifact serves end-to-end on the sharded
/// pool with the calibration it embeds — kernels come from the stored CSR
/// blobs, outputs match the golden forward of the reconstructed network.
#[test]
fn compressed_artifact_serves_end_to_end_on_the_pool() {
    let net = compress::prune_qnetwork(&random_qnet(&quickstart(), 0xA1), 0.9);
    let model = CompressedModel::from_network(&net, 0.75, 0.02, 0.9, 0.89).unwrap();
    let path = tmp_dir().join("pool.rpz");
    save_artifact(&path, &model).unwrap();

    let factory = EngineFactory::for_artifact(
        &path,
        "native",
        4,
        zynq_dnn::runtime::default_artifacts_dir(),
        1,
    )
    .unwrap();
    // the embedded calibration picked the sparse kernels, no flag involved
    assert!(factory
        .compile_plan()
        .unwrap()
        .kernels()
        .iter()
        .all(|&k| k == KernelKind::SparseQ));
    let golden = factory.net.clone();

    let cfg = ServerConfig {
        workers: 2,
        batch: 4,
        batch_deadline_us: 500,
        artifact: path.display().to_string(),
        ..Default::default()
    };
    let pool = ServePool::start(&cfg, factory).unwrap();
    let mut pairs = Vec::new();
    for i in 0..16u64 {
        let input = rand_x(1, 64, 0xB0 + i).data;
        let prio = if i % 4 == 0 {
            Priority::Interactive
        } else {
            Priority::Bulk
        };
        let ticket = pool.submit(input.clone(), SubmitOptions::with_priority(prio));
        pairs.push((input, ticket.unwrap()));
    }
    for (i, (input, mut ticket)) in pairs.into_iter().enumerate() {
        let resp = ticket.wait_timeout(Duration::from_secs(10)).unwrap();
        let want = forward_q(&golden, &MatI::from_vec(1, 64, input)).unwrap();
        assert_eq!(resp.output, want.row(0), "request {i}");
    }
    pool.shutdown().unwrap();
}

fn rand_sparse(rows: usize, cols: usize, density: f64, seed: u64) -> MatI {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut m = MatI::zeros(rows, cols);
    for v in m.data.iter_mut() {
        if rng.bernoulli(density) {
            *v = rng.below(65535) as i32 - 32767;
        }
    }
    m
}

/// ISSUE property: the delta/Huffman column payload decodes back to the
/// exact column indices across random shapes and densities (including the
/// all-zero and fully-dense corners and gaps ≥ 256).
#[test]
fn prop_encoded_columns_roundtrip_bit_exact() {
    prop_check(40, |g| {
        let rows = g.usize(1..25);
        let cols = g.usize(1..500);
        let density = g.f64(0.0, 1.0);
        let m = rand_sparse(rows, cols, density, g.u64(0..=u64::MAX / 2));
        let csr = CsrMatI::from_dense(&m);
        let payload = compress::encoding::encode_columns(&csr);
        let back =
            compress::encoding::decode_columns(&payload, csr.row_ptr(), csr.cols()).unwrap();
        back.as_slice() == csr.col_idx()
    });
}

/// ISSUE property: codebook quantization always yields ≤ 16 non-zero
/// levels, the packed 4-bit form round-trips losslessly, and the sparsity
/// pattern is untouched — across random architectures and prune factors.
#[test]
fn prop_codebook_roundtrip_preserves_quantized_matrix() {
    prop_check(40, |g| {
        let rows = g.usize(1..25);
        let cols = g.usize(1..60);
        let density = 1.0 - g.f64(0.0, 1.0); // prune factor sweep
        let m = rand_sparse(rows, cols, density, g.u64(0..=u64::MAX / 2));
        let q = codebook_quantize_matrix(&m);
        // same sparsity pattern
        if m.data.iter().zip(q.data.iter()).any(|(&a, &b)| (a == 0) != (b == 0)) {
            return false;
        }
        let csr = CsrMatI::from_dense(&q);
        let cb = CsrCodebookMatI::from_csr(&csr).unwrap();
        cb.to_csr().to_dense().data == q.data
    });
}

/// ISSUE property: the activation-skip kernel is bit-equal to plain CSR
/// SpMM on random batches with mixed zero/non-zero columns.
#[test]
fn prop_activation_skip_kernel_bit_equal_plain_csr() {
    prop_check(40, |g| {
        let rows = g.usize(1..30);
        let cols = g.usize(1..40);
        let seed = g.u64(0..=u64::MAX / 2);
        let w = CsrMatI::from_dense(&rand_sparse(rows, cols, g.f64(0.05, 0.8), seed));
        let n = g.usize(1..8);
        let mut x = rand_x(n, cols, seed ^ 0x5C1B);
        // kill a random subset of columns wholesale (what ReLU does)
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xDEAD);
        let zero_frac = g.f64(0.0, 1.0);
        for c in 0..cols {
            if rng.bernoulli(zero_frac) {
                for r in 0..n {
                    x.data[r * cols + c] = 0;
                }
            }
        }
        let mut mask = Vec::new();
        column_nonzero_mask(&x, &mut mask);
        let mut plain = MatI::zeros(n, rows);
        let mut skip = MatI::zeros(n, rows);
        spmm_i32(&x, &w, &mut plain);
        spmm_i32_opt(&x, &w, &mut skip, None, Some(&mask));
        plain.data == skip.data
    });
}
