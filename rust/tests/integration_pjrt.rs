//! Integration: PJRT artifacts (Layers 1+2) vs the rust golden model.
//! The AOT HLO must be *bit-identical* to `forward_q` — this is the
//! contract that lets the coordinator swap backends freely.
//!
//! Requires `make artifacts`; tests skip with a clear message otherwise.

use zynq_dnn::bench::random_qnet;
use zynq_dnn::nn::forward::forward_q;
use zynq_dnn::nn::spec::by_name;
use zynq_dnn::nn::quantize_matrix;
use zynq_dnn::runtime::{default_artifacts_dir, Manifest, Runtime};
use zynq_dnn::tensor::MatF;
use zynq_dnn::util::rng::Xoshiro256;

/// The artifacts are an optional build product (`make artifacts`); tests
/// skip gracefully when they are absent so `cargo test` stays green on a
/// fresh checkout.
fn artifacts_or_skip() -> Option<std::path::PathBuf> {
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn rand_input(n: usize, cols: usize, seed: u64) -> zynq_dnn::tensor::MatI {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    quantize_matrix(&MatF::from_vec(
        n,
        cols,
        (0..n * cols).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
    ))
}

#[test]
fn manifest_consistent_with_rust_specs() {
    let Some(dir) = artifacts_or_skip() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.entries.len() >= 20, "expected the full artifact set");
    for e in &m.entries {
        let spec = by_name(&e.network).expect("manifest network known to rust");
        assert_eq!(spec.sizes, e.architecture, "{}", e.network);
        assert_eq!(spec.num_parameters(), e.num_parameters, "{}", e.network);
        assert_eq!(
            spec.weight_shapes(),
            e.weight_shapes,
            "{} weight shapes",
            e.network
        );
        assert_eq!(e.input_shape, (e.batch, spec.inputs()));
        assert_eq!(e.output_shape, (e.batch, spec.outputs()));
    }
    // every paper network has the full batch sweep
    for net in ["mnist4", "mnist8", "har4", "har6"] {
        assert_eq!(m.batches_for(net), vec![1, 2, 4, 8, 16, 32], "{net}");
    }
}

#[test]
fn quickstart_bit_exact_across_batches() {
    let Some(dir) = artifacts_or_skip() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let spec = by_name("quickstart").unwrap();
    let net = random_qnet(&spec, 0x111);
    for batch in [1usize, 4] {
        let model = rt.load("quickstart", batch).unwrap();
        let x = rand_input(batch, spec.inputs(), 0x222 + batch as u64);
        let got = model.execute(&x, &net.weights).unwrap();
        let want = forward_q(&net, &x).unwrap();
        assert_eq!(got.data, want.data, "batch {batch}");
    }
}

#[test]
fn mnist4_bit_exact_batch2() {
    let Some(dir) = artifacts_or_skip() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let spec = by_name("mnist4").unwrap();
    let net = random_qnet(&spec, 0x333);
    let model = rt.load("mnist4", 2).unwrap();
    let x = rand_input(2, 784, 0x444);
    let got = model.execute(&x, &net.weights).unwrap();
    let want = forward_q(&net, &x).unwrap();
    assert_eq!(got.data, want.data);
}

#[test]
fn har4_bit_exact_with_pruned_weights() {
    // pruned networks reuse the dense artifact (zeros in the weights)
    let Some(dir) = artifacts_or_skip() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let spec = by_name("har4").unwrap();
    let net = zynq_dnn::sim::pruning::prune_qnetwork(&random_qnet(&spec, 0x555), 0.88);
    let model = rt.load("har4", 1).unwrap();
    let x = rand_input(1, 561, 0x666);
    let got = model.execute(&x, &net.weights).unwrap();
    let want = forward_q(&net, &x).unwrap();
    assert_eq!(got.data, want.data);
}

#[test]
fn wrong_shapes_rejected() {
    let Some(dir) = artifacts_or_skip() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let spec = by_name("quickstart").unwrap();
    let net = random_qnet(&spec, 0x777);
    let model = rt.load("quickstart", 1).unwrap();
    // wrong batch
    let x = rand_input(2, 64, 1);
    assert!(model.execute(&x, &net.weights).is_err());
    // wrong weight count
    let x = rand_input(1, 64, 1);
    assert!(model.execute(&x, &net.weights[..1]).is_err());
    // unknown artifact
    assert!(rt.load("quickstart", 999).is_err());
}

#[test]
fn compile_cache_returns_same_model() {
    let Some(dir) = artifacts_or_skip() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let a = rt.load("quickstart", 1).unwrap();
    let b = rt.load("quickstart", 1).unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}
