//! Integration: every experiment harness runs end to end in quick mode and
//! passes its own shape check — the CI-sized version of `paper_eval`.

use zynq_dnn::bench;

fn quick() {
    std::env::set_var("ZDNN_QUICK", "1");
}

#[test]
fn table4_accuracy_pipeline_quick() {
    quick();
    let t = bench::table4::run();
    bench::table4::check_shape(&t).unwrap();
    // all four paper networks present, paper factors hit
    assert_eq!(t.rows.len(), 4);
    for (row, target) in t.rows.iter().zip(bench::PAPER_PRUNE_FACTORS) {
        assert!((row.target_prune - target).abs() < 1e-9);
    }
}

#[test]
fn nopt_and_combined_quick() {
    quick();
    bench::nopt::check_shape(&bench::nopt::run()).unwrap();
    bench::combined::check_shape(&bench::combined::run()).unwrap();
}

#[test]
fn ablation_quick() {
    quick();
    bench::ablation::check_shape(&bench::ablation::run()).unwrap();
}

#[test]
fn compress_budget_and_roundtrip_quick() {
    quick();
    // deterministic (seeded training + search, no wall-clock gates): every
    // budget row must hold its accuracy budget and round-trip bit-exact
    let b = bench::compress::run().unwrap();
    bench::compress::check_shape(&b).unwrap();
    assert_eq!(b.rows.len(), bench::compress::BUDGET_SWEEP.len());
    // raw / delta / codebook rung study rides the same run
    assert_eq!(b.encodings.len(), 3);
}

#[test]
fn sparse_plan_beats_dense_at_high_pruning_quick() {
    quick();
    // acceptance gate for the exec subsystem: sparse plan execution wins
    // wherever q_prune >= 0.9 (bit-equality is asserted inside run()).
    // It compares wall-clock aggregates (~5-10x margins), so severely
    // contended runners can opt out rather than report phantom failures.
    if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
        eprintln!("skipping: ZDNN_SKIP_PERF=1");
        return;
    }
    bench::sparse::check_shape(&bench::sparse::run()).unwrap();
}

#[test]
fn calibrate_crossover_quick() {
    quick();
    if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
        eprintln!("skipping: ZDNN_SKIP_PERF=1");
        return;
    }
    let c = bench::calibrate::run();
    bench::calibrate::check_shape(&c).unwrap();
    // the rendered table must tell the operator what to do with the result
    let table = bench::calibrate::render(&c);
    assert!(table.contains("--threshold") || table.contains("no crossover"));
}

#[test]
fn slo_pool_scaling_quick() {
    // acceptance gate for the sharded serving runtime: 4 workers beat 1
    // worker at every batch size, and the two-level priority queue beats
    // the single-FIFO baseline on interactive p99.  Wall-clock; contended
    // or single-core runners opt out rather than report phantom failures.
    quick();
    if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
        eprintln!("skipping: ZDNN_SKIP_PERF=1");
        return;
    }
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) < 2 {
        eprintln!("skipping: single-core host cannot show worker scaling");
        return;
    }
    let b = bench::slo::run();
    bench::slo::check_shape(&b).unwrap();
    assert_eq!(b.rows.len(), 2 * 3, "quick mode: batches {{1,25}} x workers {{1,2,4}}");
}

#[test]
fn net_pipelining_beats_lockstep_quick() {
    // acceptance gates for the wire bench: a single pipelined connection
    // at depth 16 must beat the same connection at depth 1 (≙ v1
    // lockstep), v3 binary must spend < 0.3x the bytes of v2 text at rps
    // no worse, the 256-connection fan-in must lose nothing, and the
    // churn soak must leak nothing.  Wall-clock; contended or
    // single-core runners opt out rather than report phantom failures.
    quick();
    if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
        eprintln!("skipping: ZDNN_SKIP_PERF=1");
        return;
    }
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) < 2 {
        eprintln!("skipping: single-core host cannot overlap client and shards");
        return;
    }
    let b = bench::netbench::run();
    bench::netbench::check_shape(&b).unwrap();
    let cells = 2 * bench::netbench::DEPTH_SWEEP.len() * bench::netbench::CLIENT_SWEEP.len();
    assert_eq!(b.rows.len(), cells, "protos {{v2,v3}} x depths {{1,4,16,64}} x clients {{1,4}}");
}

#[test]
fn renders_are_nonempty_and_contain_paper_refs() {
    quick();
    let t2 = bench::table2::render(&bench::table2::run());
    assert!(t2.contains("paper"));
    let f7 = bench::fig7::render(&bench::fig7::run());
    assert!(f7.contains("batch 8"));
}
