//! Integration: the cycle-level simulators vs the golden model and the
//! §4.4 closed forms — functional bit-exactness on real paper networks,
//! timing agreement with the analytic formulas — plus the serving-grade
//! `sim` backend end-to-end: engine bit-equality vs native, and a TCP
//! loopback over a `serve --backend sim` pool.

use std::sync::Arc;

use zynq_dnn::bench::random_qnet;
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::{Engine as _, EngineFactory, NetClient, NetFrontend, Priority};
use zynq_dnn::nn::forward::forward_q;
use zynq_dnn::nn::spec::{har_4, mnist_4, paper_networks, quickstart, NetworkSpec};
use zynq_dnn::nn::quantize_matrix;
use zynq_dnn::perfmodel::hw::{per_sample_time, HwConfig};
use zynq_dnn::serve::start_serving;
use zynq_dnn::sim::batch::BatchAccelerator;
use zynq_dnn::sim::pruning::{prune_qnetwork, PruningAccelerator, SparseNetwork};
use zynq_dnn::tensor::{MatF, MatI};
use zynq_dnn::util::rng::Xoshiro256;

fn rand_input(n: usize, cols: usize, seed: u64) -> zynq_dnn::tensor::MatI {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    quantize_matrix(&MatF::from_vec(
        n,
        cols,
        (0..n * cols).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
    ))
}

#[test]
fn batch_sim_bit_exact_on_mnist4() {
    let net = random_qnet(&mnist_4(), 1);
    for batch in [1usize, 4] {
        let acc = BatchAccelerator::zedboard(batch);
        let x = rand_input(batch, 784, 2);
        let (y, t) = acc.run(&net, &x).unwrap();
        assert_eq!(y.data, forward_q(&net, &x).unwrap().data, "batch {batch}");
        assert!(t.total_seconds > 0.0);
    }
}

#[test]
fn pruning_sim_bit_exact_on_har4_at_paper_factor() {
    let net = prune_qnetwork(&random_qnet(&har_4(), 3), 0.88);
    let snet = SparseNetwork::encode(&net).unwrap();
    let acc = PruningAccelerator::zedboard();
    let x = rand_input(2, 561, 4);
    let (y, _) = acc.run(&snet, &x).unwrap();
    assert_eq!(y.data, forward_q(&net, &x).unwrap().data);
}

#[test]
fn batch_sim_tracks_closed_form_within_overheads() {
    // sim = closed form + (prologue + drain + per-sample software overhead);
    // the pure t_proc part must agree within 5% once overheads are removed
    for spec in paper_networks() {
        let net = random_qnet(&spec, 5);
        for batch in [1usize, 16] {
            let acc = BatchAccelerator::zedboard(batch);
            let sim = acc.timing_only(&net);
            let cfg = HwConfig::batch_design(acc.m, batch, acc.memory.effective());
            let formula = per_sample_time(&cfg, &spec, &[]);
            let sim_core =
                (sim.total_seconds - acc.sample_overhead * batch as f64) / batch as f64;
            let rel = (sim_core - formula).abs() / formula;
            assert!(
                rel < 0.30,
                "{} batch {batch}: sim-core {sim_core:.6} vs formula {formula:.6} ({rel:.2})",
                spec.name
            );
        }
    }
}

#[test]
fn pruning_sim_memory_accounting_matches_encoder() {
    let net = prune_qnetwork(&random_qnet(&har_4(), 6), 0.9);
    let snet = SparseNetwork::encode(&net).unwrap();
    let acc = PruningAccelerator::zedboard();
    let t = acc.timing_only(&snet);
    assert_eq!(t.total_weight_bytes(), snet.stream_bytes());
}

#[test]
fn sim_batch_weight_bytes_equal_2_per_param() {
    for spec in paper_networks() {
        let net = random_qnet(&spec, 7);
        let t = BatchAccelerator::zedboard(8).timing_only(&net);
        assert_eq!(
            t.total_weight_bytes() as usize,
            2 * spec.num_parameters(),
            "{}",
            spec.name
        );
    }
}

#[test]
fn all_backends_agree_on_one_network() {
    // native, batch sim, pruning sim (at q=0 the sparse stream is dense)
    let net = random_qnet(&har_4(), 8);
    let x = rand_input(2, 561, 9);
    let golden = forward_q(&net, &x).unwrap();

    let (y_batch, _) = BatchAccelerator::zedboard(2).run(&net, &x).unwrap();
    assert_eq!(y_batch.data, golden.data);

    let snet = SparseNetwork::encode(&net).unwrap();
    let (y_sparse, _) = PruningAccelerator::zedboard().run(&snet, &x).unwrap();
    assert_eq!(y_sparse.data, golden.data);
}

// ---- the serving-grade `sim` backend -------------------------------------

fn factory(spec: &NetworkSpec, backend: &str, batch: usize, seed: u64) -> EngineFactory {
    EngineFactory {
        backend: backend.into(),
        batch,
        net: random_qnet(spec, seed),
        artifacts_dir: zynq_dnn::runtime::default_artifacts_dir(),
        native_threads: 1,
        sparse_threshold: None,
        artifact: None,
    }
}

/// The `sim` engine must be bit-identical to the native engine on random
/// networks and batch sizes, while reporting the modeled (not wall-clock)
/// batch time.
#[test]
fn sim_engine_bit_equal_to_native_on_random_networks() {
    for (spec, s_in) in [(quickstart(), 64), (mnist_4(), 784), (har_4(), 561)] {
        for batch in [1usize, 4] {
            let seed = 0x100 + batch as u64;
            let mut native = factory(&spec, "native", batch, seed).build().unwrap();
            let mut sim = factory(&spec, "sim", batch, seed).build().unwrap();
            let x = rand_input(batch, s_in, seed + 1);
            assert_eq!(
                sim.infer(&x).unwrap().data,
                native.infer(&x).unwrap().data,
                "{} batch {batch}",
                spec.name
            );
            let net = random_qnet(&spec, seed);
            let expect = BatchAccelerator::zedboard(batch).timing_only(&net).total_seconds;
            let got = sim.simulated_seconds().unwrap();
            assert!((got - expect).abs() < 1e-15, "{} {got} vs {expect}", spec.name);
            assert!(native.simulated_seconds().is_none(), "native reports wall-clock");
        }
    }
}

/// Full TCP loopback over `serve --backend sim`: a 2-shard pool of sim
/// engines behind the network frontend must answer mixed-priority INFER
/// traffic with golden outputs — the whole wire + pool + engine stack on
/// simulated Zynq timing with zero special cases.
#[test]
fn serve_sim_backend_over_tcp_loopback() {
    let spec = quickstart();
    let factory = factory(&spec, "sim", 2, 0x77);
    let net = factory.net.clone();
    let cfg = ServerConfig {
        network: spec.name.clone(),
        workers: 2,
        batch: 2,
        batch_deadline_us: 300,
        queue_depth: 256,
        backend: "sim".into(),
        ..Default::default()
    };
    let serving = Arc::new(start_serving(&cfg, factory).unwrap());
    let fe = NetFrontend::start("127.0.0.1:0", serving.clone()).unwrap();
    let mut client = NetClient::connect(&fe.addr()).unwrap();
    client.set_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    let mut rng = Xoshiro256::seed_from_u64(0x78);
    for i in 0..12 {
        let vals: Vec<f32> = (0..64).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let prio = if i % 3 == 0 { Priority::Interactive } else { Priority::Bulk };
        let (class, out) = client.infer_with(&vals, prio).unwrap();
        let q = zynq_dnn::fixedpoint::quantize_slice(&vals);
        let want = forward_q(&net, &MatI::from_vec(1, 64, q)).unwrap();
        assert_eq!(out, want.row(0), "request {i}");
        assert!(class < 10);
    }
    let stats = client.stats().unwrap();
    assert!(stats.contains("requests=12"), "{stats}");
    client.quit().unwrap();
    fe.stop();
}
