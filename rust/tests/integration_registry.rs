//! Integration: the PR 8 multi-model registry over TCP — `@<model>`
//! routing, `MODELS`, and the zero-downtime `SWAP` under live load.
//!
//! What this locks in (the PR 8 acceptance surface):
//!
//! * mixed-priority tagged load keeps flowing on one connection while a
//!   second connection hot-swaps the default model: every ticket gets
//!   exactly one reply, and every reply bit-matches one of the two
//!   versions' golden forward passes (nothing lost, nothing corrupted),
//! * requests submitted after the swap returns serve the new version
//!   exclusively, and `MODELS` reports the bumped version,
//! * `INFER @<model>` routes explicitly (each model's own golden),
//!   an unloaded name fails only its own ticket with a tagged
//!   "unknown model" error, and the connection stays healthy after.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use zynq_dnn::bench::random_qnet;
use zynq_dnn::compress::{save_artifact, CompressedModel};
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::{NetClient, NetFrontend, Priority};
use zynq_dnn::nn::spec::quickstart;
use zynq_dnn::nn::{forward_q, QNetwork};
use zynq_dnn::registry::Registry;
use zynq_dnn::sim::pruning::prune_qnetwork;
use zynq_dnn::tensor::MatI;

/// Write a quickstart-shaped `.rpz` and return the exact network it
/// decodes to — the golden weights the server will serve.
fn write_rpz(dir: &Path, file: &str, seed: u64) -> (PathBuf, QNetwork) {
    let net = prune_qnetwork(&random_qnet(&quickstart(), seed), 0.9);
    let model = CompressedModel::from_network(&net, 0.75, 0.02, 0.9, 0.89).unwrap();
    let served = model.to_qnetwork().unwrap();
    let path = dir.join(file);
    save_artifact(&path, &model).unwrap();
    (path, served)
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("zdnn-it-registry-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn values_for(seed: usize) -> Vec<f32> {
    (0..64)
        .map(|k| ((k * 7 + seed * 13) % 101) as f32 / 101.0 - 0.5)
        .collect()
}

fn golden_for(net: &QNetwork, values: &[f32]) -> Vec<i32> {
    let xq = zynq_dnn::fixedpoint::quantize_slice(values);
    forward_q(net, &MatI::from_vec(1, 64, xq)).unwrap().row(0).to_vec()
}

fn start_registry(models: String, workers: usize) -> (NetFrontend, Arc<Registry>) {
    let cfg = ServerConfig {
        models,
        workers,
        batch: 4,
        batch_deadline_us: 300,
        queue_depth: 4096,
        ..Default::default()
    };
    let registry = Arc::new(Registry::start(&cfg).unwrap());
    let fe = NetFrontend::start("127.0.0.1:0", registry.clone()).unwrap();
    (fe, registry)
}

/// The headline acceptance test: pipelined mixed-priority load rides one
/// connection while a second connection swaps the default model.  Every
/// ticket resolves exactly once to one of the two versions' goldens;
/// post-swap traffic serves v2 only; `MODELS` reflects the bump.
#[test]
fn hot_swap_under_live_tcp_load_loses_nothing() {
    let dir = temp_dir("swap");
    let (p1, net_v1) = write_rpz(&dir, "m-v1.rpz", 0x51);
    let (p2, net_v2) = write_rpz(&dir, "m-v2.rpz", 0x52);
    let (pa, net_aux) = write_rpz(&dir, "aux.rpz", 0x53);
    let models = format!("m={}@3,aux={}@1", p1.display(), pa.display());
    let (fe, registry) = start_registry(models, 4);

    let mut client = NetClient::connect(&fe.addr()).unwrap();
    let mut tickets = Vec::new();
    // phase A: pre-swap load (plain INFER routes to the default model m)
    for i in 0..24usize {
        let prio = if i % 3 == 0 { Priority::Interactive } else { Priority::Bulk };
        tickets.push((i, client.submit(&values_for(i), prio).unwrap()));
    }
    // the swap runs on its own connection so the load connection's
    // pipeline never blocks behind the drain
    let swap_addr = fe.addr();
    let p2_str = p2.display().to_string();
    let swapper = std::thread::spawn(move || {
        let mut admin = NetClient::connect(&swap_addr).unwrap();
        admin.set_timeout(Some(Duration::from_secs(120))).unwrap();
        let summary = admin.swap("m", &p2_str).unwrap();
        admin.quit().unwrap();
        summary
    });
    // phase B: keep the pipeline full while the swap is in flight
    let mut i = 24usize;
    while !swapper.is_finished() && i < 600 {
        let prio = if i % 3 == 0 { Priority::Interactive } else { Priority::Bulk };
        tickets.push((i, client.submit(&values_for(i), prio).unwrap()));
        i += 1;
        if i % 8 == 0 {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let summary = swapper.join().unwrap();
    assert!(summary.starts_with("SWAP m v1 -> v2"), "{summary}");

    // every phase A/B ticket gets exactly one reply matching one version
    let (mut v1_replies, mut v2_replies) = (0usize, 0usize);
    let total = tickets.len();
    for (j, mut ticket) in tickets {
        let resp = ticket
            .wait_timeout(Duration::from_secs(30))
            .unwrap_or_else(|e| panic!("request {j} lost across the swap: {e}"));
        if resp.outputs == golden_for(&net_v1, &values_for(j)) {
            v1_replies += 1;
        } else if resp.outputs == golden_for(&net_v2, &values_for(j)) {
            v2_replies += 1;
        } else {
            panic!("request {j}: reply matches neither version's golden");
        }
    }
    assert_eq!(v1_replies + v2_replies, total, "nothing lost, nothing duplicated");
    assert!(v1_replies > 0, "pre-swap requests completed on the old version");

    // phase C: post-swap traffic serves v2 exclusively
    for j in 700..710usize {
        let mut t = client.submit(&values_for(j), Priority::Interactive).unwrap();
        let resp = t.wait_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.outputs, golden_for(&net_v2, &values_for(j)), "post-swap {j}");
    }
    // MODELS reflects the bump; aux is untouched
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let lines = client.models().unwrap();
    assert_eq!(lines.len(), 2);
    let m_line = lines.iter().find(|l| l.contains("name=m ")).unwrap();
    assert!(m_line.contains("version=2"), "{m_line}");
    let aux_line = lines.iter().find(|l| l.contains("name=aux")).unwrap();
    assert!(aux_line.contains("version=1"), "{aux_line}");
    assert_eq!(registry.swaps_total(), 1);

    // aux still serves its own golden through explicit routing
    let mut t = client.submit_to(Some("aux"), &values_for(42), Priority::Bulk).unwrap();
    let resp = t.wait_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(resp.outputs, golden_for(&net_aux, &values_for(42)));

    client.quit().unwrap();
    fe.stop();
}

/// The wire surface around routing: `@<model>` picks the named model's
/// weights, an unloaded name fails only its own ticket (tagged ERR), and
/// the connection keeps serving afterwards.
#[test]
fn model_routing_and_unknown_model_errors_over_tcp() {
    let dir = temp_dir("route");
    let (pa, net_a) = write_rpz(&dir, "alpha.rpz", 0x61);
    let (pb, net_b) = write_rpz(&dir, "beta.rpz", 0x62);
    let models = format!("alpha={}@1,beta={}@1", pa.display(), pb.display());
    let (fe, _registry) = start_registry(models, 2);
    let mut client = NetClient::connect(&fe.addr()).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();

    // explicit routing to each model, pipelined and interleaved
    let mut pairs = Vec::new();
    for j in 0..6usize {
        let name = if j % 2 == 0 { "alpha" } else { "beta" };
        let ticket = client.submit_to(Some(name), &values_for(j), Priority::Bulk).unwrap();
        pairs.push((j, name, ticket));
    }
    // an unloaded model fails exactly its own ticket…
    let mut bogus = client.submit_to(Some("ghost"), &values_for(9), Priority::Interactive).unwrap();
    let e = bogus.wait_timeout(Duration::from_secs(10)).unwrap_err();
    assert!(e.to_string().contains("unknown model"), "{e}");
    // …while the in-flight routed requests resolve to their own goldens
    for (j, name, mut ticket) in pairs {
        let resp = ticket.wait_timeout(Duration::from_secs(30)).unwrap();
        let net = if name == "alpha" { &net_a } else { &net_b };
        assert_eq!(resp.outputs, golden_for(net, &values_for(j)), "request {j} @{name}");
    }
    // plain INFER still routes to the default (first spec = alpha)
    let (_, outputs) = client.infer(&values_for(77)).unwrap();
    assert_eq!(outputs, golden_for(&net_a, &values_for(77)));
    client.quit().unwrap();
    fe.stop();
}
