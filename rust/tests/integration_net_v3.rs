//! Integration: wire protocol v3 over real TCP against the readiness-
//! driven frontend — the PR 9 acceptance surface.
//!
//! What this locks in:
//!
//! * binary frames pipeline over a real socket with bit-exact outputs
//!   (single-sample and batch-of-N frames, f32 and i16 payloads),
//! * all three wire generations interleave on ONE connection via
//!   first-byte sniffing,
//! * the frame's relative deadline reaches the server-side shedder: an
//!   expired request comes back `REPLY_ERR` without touching an engine,
//! * malformed frames (bad magic, bad version, truncated, oversized
//!   declared length) get frame-scoped errors with the allocation guard
//!   holding; the connection survives where the stream stays parseable,
//! * `max_conns` bounds the accept path with an `ERR busy` reply,
//! * open/infer/close churn leaks neither fds nor threads, and `stop()`
//!   returns bounded with idle v3 connections attached (no read polling
//!   left in the frontend).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use zynq_dnn::bench::random_qnet;
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::net::frame;
use zynq_dnn::coordinator::{EngineFactory, NetClient, NetFrontend, NetOptions, Priority};
use zynq_dnn::nn::forward_q;
use zynq_dnn::nn::spec::quickstart;
use zynq_dnn::serve::{start_serving, Serving};
use zynq_dnn::tensor::MatI;

fn start_stack_with(
    workers: usize,
    batch: usize,
    batch_deadline_us: u64,
    opts: NetOptions,
) -> (NetFrontend, Arc<Serving>, zynq_dnn::nn::QNetwork) {
    let net = random_qnet(&quickstart(), 0xC3);
    let factory = EngineFactory {
        backend: "native".into(),
        batch,
        net: net.clone(),
        artifacts_dir: zynq_dnn::runtime::default_artifacts_dir(),
        native_threads: 1,
        sparse_threshold: None,
        artifact: None,
    };
    let cfg = ServerConfig {
        workers,
        batch,
        batch_deadline_us,
        queue_depth: 4096,
        ..Default::default()
    };
    let serving = Arc::new(start_serving(&cfg, factory).unwrap());
    let fe = NetFrontend::start_with("127.0.0.1:0", serving.clone(), opts).unwrap();
    (fe, serving, net)
}

fn start_stack(
    workers: usize,
    batch: usize,
) -> (NetFrontend, Arc<Serving>, zynq_dnn::nn::QNetwork) {
    start_stack_with(workers, batch, 300, NetOptions::default())
}

fn values_for(seed: usize) -> Vec<f32> {
    (0..64)
        .map(|k| ((k * 7 + seed * 13) % 101) as f32 / 101.0 - 0.5)
        .collect()
}

fn golden_for(net: &zynq_dnn::nn::QNetwork, values: &[f32]) -> (usize, Vec<i32>) {
    let xq = zynq_dnn::fixedpoint::quantize_slice(values);
    let y = forward_q(net, &MatI::from_vec(1, 64, xq)).unwrap();
    let class = zynq_dnn::nn::forward::argmax_rows(&y)[0];
    (class, y.row(0).to_vec())
}

/// Read one complete v3 frame off a raw socket: `(kind, body)`.
fn read_frame(r: &mut impl Read) -> std::io::Result<(u8, Vec<u8>)> {
    let mut prelude = [0u8; frame::PRELUDE_LEN];
    r.read_exact(&mut prelude)?;
    let hdr = frame::parse_prelude(&prelude).expect("well-formed reply prelude");
    let mut body = vec![0u8; hdr.body_len];
    r.read_exact(&mut body)?;
    Ok((hdr.kind, body))
}

/// Binary requests pipeline over real TCP with bit-exact outputs: a
/// 16-deep window of single-sample frames, then batch-of-4 frames, on
/// both payload encodings.
#[test]
fn binary_pipelining_bit_exact_over_tcp() {
    let (fe, _serving, net) = start_stack(4, 4);
    let mut client = NetClient::connect(&fe.addr()).unwrap();
    let mut window = std::collections::VecDeque::new();
    let mut inputs = std::collections::VecDeque::new();
    for i in 0..80usize {
        if window.len() == 16 {
            let mut t: zynq_dnn::coordinator::NetTicket = window.pop_front().unwrap();
            let vals: Vec<f32> = inputs.pop_front().unwrap();
            let resp = t.wait_timeout(Duration::from_secs(60)).unwrap();
            let (want_class, want_out) = golden_for(&net, &vals);
            assert_eq!(resp.outputs, want_out);
            assert_eq!(resp.class, want_class);
        }
        let vals = values_for(i);
        window.push_back(client.submit_binary(&vals, Priority::Interactive).unwrap());
        inputs.push_back(vals);
    }
    for mut t in window {
        let vals: Vec<f32> = inputs.pop_front().unwrap();
        let resp = t.wait_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.outputs, golden_for(&net, &vals).1);
    }
    // batch-of-4 in ONE frame, i16 payload: four tickets, each golden
    let rows: Vec<Vec<f32>> = (100..104).map(values_for).collect();
    let qrows: Vec<Vec<i16>> = rows
        .iter()
        .map(|r| {
            r.iter()
                .map(|&v| zynq_dnn::fixedpoint::quantize(v as f64) as i16)
                .collect()
        })
        .collect();
    let qrefs: Vec<&[i16]> = qrows.iter().map(|r| r.as_slice()).collect();
    let tickets = client
        .submit_binary_i16(None, &qrefs, Priority::Bulk, None)
        .unwrap();
    assert_eq!(tickets.len(), 4);
    for (i, mut t) in tickets.into_iter().enumerate() {
        let resp = t.wait_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.outputs, golden_for(&net, &rows[i]).1, "batch row {i}");
    }
    client.quit().unwrap();
    fe.stop();
}

/// All three generations on ONE raw connection, sniffed per message:
/// a v1 untagged line, then a v3 binary frame, then a v2 tagged line.
#[test]
fn three_generations_interleave_on_one_connection() {
    let (fe, _serving, net) = start_stack(2, 4);
    let stream = TcpStream::connect(fe.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let vals = values_for(9);
    let (want_class, want_out) = golden_for(&net, &vals);

    // v1: untagged lockstep line
    let mut line = String::from("INFER");
    for v in &vals {
        line.push(' ');
        line.push_str(&v.to_string());
    }
    line.push('\n');
    writer.write_all(line.as_bytes()).unwrap();
    let mut reply = String::new();
    std::io::BufRead::read_line(&mut reader, &mut reply).unwrap();
    assert!(reply.starts_with("OK "), "{reply}");

    // v3: binary frame on the same socket
    let req = frame::RequestFrame {
        tag: 77,
        bulk: false,
        deadline_us: 0,
        batch: 1,
        width: 64,
        model: None,
        payload: frame::Payload::F32(vals.clone()),
    };
    writer.write_all(&frame::encode_request(&req)).unwrap();
    let (kind, body) = read_frame(&mut reader).unwrap();
    assert_eq!(kind, frame::KIND_REPLY_OK);
    let frame::ReplyFrame::Ok(ok) = frame::decode_reply(kind, &body).unwrap() else {
        panic!("expected OK reply frame");
    };
    assert_eq!(ok.tag, 77);
    assert_eq!(ok.index, 0);
    assert_eq!(ok.outputs, want_out);
    assert_eq!(ok.class as usize, want_class);

    // v2: tagged text, still on the same socket
    let mut line = String::from("INFER #5");
    for v in &vals {
        line.push(' ');
        line.push_str(&v.to_string());
    }
    line.push('\n');
    writer.write_all(line.as_bytes()).unwrap();
    let mut reply = String::new();
    std::io::BufRead::read_line(&mut reader, &mut reply).unwrap();
    assert!(reply.starts_with("OK #5 "), "{reply}");

    writer.write_all(b"QUIT\n").unwrap();
    let mut rest = String::new();
    reader.read_to_string(&mut rest).unwrap();
    assert!(rest.is_empty(), "QUIT closes silently, got {rest:?}");
    fe.stop();
}

/// The frame's relative deadline lights up PR 8's server-side shedder
/// over the wire: with a long batch-formation deadline, an
/// already-expired request is shed with `REPLY_ERR` while an
/// undeadlined sibling completes.
#[test]
fn deadline_shed_over_binary_wire() {
    // batch 4 never fills from one client, so formation waits the full
    // 200 ms flush deadline — plenty for a 1 µs request deadline to lapse
    let (fe, _serving, net) = start_stack_with(1, 4, 200_000, NetOptions::default());
    let mut client = NetClient::connect(&fe.addr()).unwrap();
    let vals = values_for(3);
    let q: Vec<i16> = vals
        .iter()
        .map(|&v| zynq_dnn::fixedpoint::quantize(v as f64) as i16)
        .collect();
    let mut doomed = client
        .submit_binary_i16(None, &[&q], Priority::Interactive, Some(Duration::from_micros(1)))
        .unwrap()
        .pop()
        .unwrap();
    let e = doomed.wait_timeout(Duration::from_secs(30)).unwrap_err();
    let msg = e.to_string();
    assert!(msg.contains("shed") || msg.contains("deadline"), "{msg}");
    // no deadline: same wire, same connection, completes fine
    let mut t = client
        .submit_binary_i16(None, &[&q], Priority::Interactive, None)
        .unwrap()
        .pop()
        .unwrap();
    let resp = t.wait_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(resp.outputs, golden_for(&net, &vals).1);
    // the shed is visible in the uniform STATS payload
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.contains("shed=1"), "{stats}");
    client.quit().unwrap();
    fe.stop();
}

/// Malformed binary traffic gets frame-scoped errors; the connection
/// survives whenever the stream stays parseable, and the oversized-
/// declared-length guard answers without allocating the claimed body.
#[test]
fn malformed_frames_scoped_err_and_guarded_allocation() {
    let (fe, _serving, net) = start_stack(2, 4);
    let stream = TcpStream::connect(fe.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    // bad magic is just a text line: ERR reply, connection lives
    writer.write_all(b"XYZZY plugh\n").unwrap();
    let mut reply = String::new();
    std::io::BufRead::read_line(&mut reader, &mut reply).unwrap();
    assert!(reply.starts_with("ERR"), "{reply}");

    // oversized declared length: REPLY_ERR carries the echoed tag and the
    // cap, the declared body is stream-discarded (never allocated), and
    // the connection resyncs for valid traffic afterwards
    let declared = frame::MAX_FRAME_BYTES + 1;
    let mut evil = Vec::new();
    evil.push(frame::MAGIC);
    evil.push(frame::VERSION);
    evil.push(frame::KIND_REQ);
    evil.push(0u8); // flags
    evil.extend_from_slice(&(declared as u32).to_le_bytes());
    evil.extend_from_slice(&0xDEADu64.to_le_bytes()); // tag prefix of the body
    writer.write_all(&evil).unwrap();
    let (kind, body) = read_frame(&mut reader).unwrap();
    assert_eq!(kind, frame::KIND_REPLY_ERR);
    let frame::ReplyFrame::Err(err) = frame::decode_reply(kind, &body).unwrap() else {
        panic!("expected ERR reply frame");
    };
    assert_eq!(err.tag, 0xDEAD, "tag echoed so the client can route the error");
    assert!(err.msg.contains("frame too large"), "{}", err.msg);
    // feed the rest of the declared body as junk; the server discards it
    let mut remaining = declared - 8;
    let junk = vec![0u8; 1 << 16];
    while remaining > 0 {
        let n = remaining.min(junk.len());
        writer.write_all(&junk[..n]).unwrap();
        remaining -= n;
    }
    // resynced: a valid frame round-trips golden on the same connection
    let vals = values_for(11);
    let req = frame::RequestFrame {
        tag: 42,
        bulk: false,
        deadline_us: 0,
        batch: 1,
        width: 64,
        model: None,
        payload: frame::Payload::F32(vals.clone()),
    };
    writer.write_all(&frame::encode_request(&req)).unwrap();
    let (kind, body) = read_frame(&mut reader).unwrap();
    assert_eq!(kind, frame::KIND_REPLY_OK);
    let frame::ReplyFrame::Ok(ok) = frame::decode_reply(kind, &body).unwrap() else {
        panic!("expected OK reply frame");
    };
    assert_eq!(ok.tag, 42);
    assert_eq!(ok.outputs, golden_for(&net, &vals).1);

    // bad version: the stream offset is untrustworthy, so the server
    // answers one ERR frame (tag 0) and closes
    let stream2 = TcpStream::connect(fe.addr()).unwrap();
    stream2.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader2 = std::io::BufReader::new(stream2.try_clone().unwrap());
    let mut writer2 = stream2;
    writer2
        .write_all(&[frame::MAGIC, 9, frame::KIND_REQ, 0, 4, 0, 0, 0])
        .unwrap();
    let (kind, body) = read_frame(&mut reader2).unwrap();
    assert_eq!(kind, frame::KIND_REPLY_ERR);
    let frame::ReplyFrame::Err(err) = frame::decode_reply(kind, &body).unwrap() else {
        panic!("expected ERR reply frame");
    };
    assert_eq!(err.tag, 0);
    let mut rest = Vec::new();
    reader2.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "connection closes after a bad version");

    // truncated prelude then EOF: the server just drops the connection
    let stream3 = TcpStream::connect(fe.addr()).unwrap();
    stream3.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer3 = stream3.try_clone().unwrap();
    writer3.write_all(&[frame::MAGIC, frame::VERSION, frame::KIND_REQ]).unwrap();
    writer3.shutdown(std::net::Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    stream3.try_clone().unwrap().read_to_end(&mut buf).unwrap();
    assert!(buf.is_empty(), "no reply for a frame that never completed");

    writer.write_all(b"QUIT\n").unwrap();
    fe.stop();
}

/// `max_conns` bounds the accept path: over-cap connections get one
/// `ERR busy` line and a close, counted in `conn_rejected=`, and a slot
/// frees once a capped connection leaves.
#[test]
fn max_conns_cap_bounds_the_accept_path() {
    let (fe, _serving, _net) = start_stack_with(
        2,
        4,
        300,
        NetOptions { max_conns: 2, accept_v3: true },
    );
    let mut a = NetClient::connect(&fe.addr()).unwrap();
    let mut b = NetClient::connect(&fe.addr()).unwrap();
    a.set_timeout(Some(Duration::from_secs(30))).unwrap();
    b.set_timeout(Some(Duration::from_secs(30))).unwrap();
    // a round trip each proves both are registered, not racing the accept
    a.stats().unwrap();
    b.stats().unwrap();
    let mut raw = TcpStream::connect(fe.addr()).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut text = String::new();
    raw.read_to_string(&mut text).unwrap();
    assert!(text.starts_with("ERR busy"), "{text:?}");
    let stats = a.stats().unwrap();
    assert!(stats.contains("conn_rejected=1"), "{stats}");
    // free a slot; the frontend notices on its next wake, so retry briefly
    b.quit().unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let mut c = NetClient::connect(&fe.addr()).unwrap();
        c.set_timeout(Some(Duration::from_secs(5))).unwrap();
        // a rejected connection answers "ERR busy" to anything; only a
        // real STATS line proves the freed slot was granted
        if c.stats().map(|s| s.starts_with("STATS ")).unwrap_or(false) {
            c.quit().unwrap();
            break;
        }
        assert!(Instant::now() < deadline, "freed slot never became acceptable");
        std::thread::sleep(Duration::from_millis(50));
    }
    a.quit().unwrap();
    fe.stop();
}

/// Open/infer/close churn over the v3 wire leaks neither file
/// descriptors nor threads: the frontend's thread count is fixed and
/// per-connection state dies with the connection.
#[test]
fn connection_churn_leaks_nothing() {
    let (fe, _serving, net) = start_stack(2, 4);
    #[cfg(target_os = "linux")]
    let count = |p: &str| std::fs::read_dir(p).map(|d| d.count() as i64).unwrap_or(-1);
    #[cfg(target_os = "linux")]
    let (fd_base, th_base) = (count("/proc/self/fd"), count("/proc/self/task"));
    for i in 0..60usize {
        let mut c = NetClient::connect(&fe.addr()).unwrap();
        c.set_timeout(Some(Duration::from_secs(30))).unwrap();
        let vals = values_for(i);
        let (_, out) = c.infer_binary(&vals).unwrap();
        assert_eq!(out, golden_for(&net, &vals).1, "cycle {i}");
        c.quit().unwrap();
    }
    // server-side teardown is asynchronous; let the populations settle
    #[cfg(target_os = "linux")]
    {
        let mut fd_now = count("/proc/self/fd");
        let mut th_now = count("/proc/self/task");
        for _ in 0..40 {
            if fd_now <= fd_base && th_now <= th_base {
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
            fd_now = count("/proc/self/fd");
            th_now = count("/proc/self/task");
        }
        assert!(fd_now <= fd_base, "leaked fds: {fd_base} -> {fd_now}");
        assert!(th_now <= th_base, "leaked threads: {th_base} -> {th_now}");
    }
    let open = fe
        .net_stats()
        .connections_open
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(open, 0, "every churned connection deregistered");
    let total = fe
        .net_stats()
        .connections_total
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(total, 60);
    fe.stop();
}

/// `stop()` returns bounded with idle v3 connections attached — the
/// waker interrupts the indefinite poll; nothing 50 ms-polls anymore.
#[test]
fn stop_is_bounded_with_idle_v3_connections() {
    let (fe, _serving, _net) = start_stack(2, 4);
    let mut idlers = Vec::new();
    for i in 0..8usize {
        let mut c = NetClient::connect(&fe.addr()).unwrap();
        c.set_timeout(Some(Duration::from_secs(30))).unwrap();
        // one binary round trip marks the connection live on the v3 path
        c.infer_binary(&values_for(i)).unwrap();
        idlers.push(c);
    }
    let t0 = Instant::now();
    fe.stop();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "stop() took {:?} with idle connections",
        t0.elapsed()
    );
    drop(idlers);
}
