//! Integration: wire protocol v2 — tagged, pipelined submissions over one
//! connection, with the writer-side reply demux and the pipelined
//! `NetClient`.
//!
//! What this locks in (the PR 5 acceptance surface):
//!
//! * one connection holds many in-flight tagged requests against the
//!   4-worker pool, every ticket resolving to its own golden reply,
//! * v1 untagged lockstep calls and v2 tagged pipelining interleave on
//!   the same connection without cross-talk,
//! * the reply demux matches tickets to replies **exactly once** under
//!   random out-of-order completion orders across priorities, with
//!   engine errors routed to exactly the failing request's ticket
//!   (property-tested against a completion-shuffling mock target),
//! * dropping a connection mid-pipeline leaks nothing: in-flight
//!   requests still complete server-side, the frontend keeps serving new
//!   connections, and `stop()` returns,
//! * per-request submission errors (wrong width / backpressure) come
//!   back as `ERR #<tag>`, scoped to their ticket, with the connection
//!   healthy afterwards.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;
use zynq_dnn::bench::random_qnet;
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::{
    EngineFactory, InferError, NetClient, NetFrontend, Priority, Reply, RequestId, Response,
    StatsReport, SubmitTarget,
};
use zynq_dnn::nn::forward_q;
use zynq_dnn::nn::spec::quickstart;
use zynq_dnn::serve::{start_serving, Serving};
use zynq_dnn::tensor::MatI;
use zynq_dnn::util::prop::prop_check;
use zynq_dnn::util::rng::Xoshiro256;

type Stack = (NetFrontend, Arc<Serving>, zynq_dnn::nn::QNetwork);

fn start_stack(workers: usize, batch: usize) -> Stack {
    let net = random_qnet(&quickstart(), 0xC0);
    let factory = EngineFactory {
        backend: "native".into(),
        batch,
        net: net.clone(),
        artifacts_dir: zynq_dnn::runtime::default_artifacts_dir(),
        native_threads: 1,
        sparse_threshold: None,
        artifact: None,
    };
    let cfg = ServerConfig {
        workers,
        batch,
        batch_deadline_us: 300,
        bulk_promote_us: 20_000,
        queue_depth: 4096,
        ..Default::default()
    };
    let serving = Arc::new(start_serving(&cfg, factory).unwrap());
    let fe = NetFrontend::start("127.0.0.1:0", serving.clone()).unwrap();
    (fe, serving, net)
}

fn values_for(seed: usize) -> Vec<f32> {
    (0..64)
        .map(|k| ((k * 7 + seed * 13) % 101) as f32 / 101.0 - 0.5)
        .collect()
}

fn golden_for(net: &zynq_dnn::nn::QNetwork, values: &[f32]) -> Vec<i32> {
    let xq = zynq_dnn::fixedpoint::quantize_slice(values);
    forward_q(net, &MatI::from_vec(1, 64, xq)).unwrap().row(0).to_vec()
}

fn pool_requests(serving: &Serving) -> u64 {
    match serving {
        Serving::Pool(p) => p.snapshot().aggregate.requests,
        Serving::Single(_) => panic!("expected a pool"),
    }
}

/// Many tagged requests in flight on ONE connection against the 4-worker
/// pool — the per-client throughput bound the v1 lockstep protocol
/// imposed — each ticket resolving to its own golden reply exactly once.
#[test]
fn pipelined_depth16_golden_replies_over_pool() {
    let (fe, serving, net) = start_stack(4, 4);
    let mut client = NetClient::connect(&fe.addr()).unwrap();
    let mut window = std::collections::VecDeque::new();
    let total = 48usize;
    let depth = 16usize;
    for i in 0..total {
        if window.len() == depth {
            let (j, mut ticket): (usize, _) = window.pop_front().unwrap();
            let resp = ticket.wait_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.outputs, golden_for(&net, &values_for(j)), "request {j}");
        }
        let prio = if i % 2 == 0 {
            Priority::Interactive
        } else {
            Priority::Bulk
        };
        window.push_back((i, client.submit(&values_for(i), prio).unwrap()));
    }
    for (j, mut ticket) in window {
        let resp = ticket.wait_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.outputs, golden_for(&net, &values_for(j)), "request {j}");
    }
    assert_eq!(pool_requests(&serving), total as u64, "exactly-once accounting");
    client.quit().unwrap();
    fe.stop();
}

/// v1 lockstep calls and v2 tagged pipelining interleave on one
/// connection: untagged replies pair with untagged requests in order
/// while tagged replies keep demuxing around them.
#[test]
fn v1_lockstep_and_v2_pipelined_mix_on_one_connection() {
    let (fe, serving, net) = start_stack(4, 4);
    let mut client = NetClient::connect(&fe.addr()).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut tickets = Vec::new();
    for i in 0..8usize {
        tickets.push((i, client.submit(&values_for(i), Priority::Bulk).unwrap()));
    }
    // lockstep in the middle of the in-flight pipeline
    let (_, outputs) = client.infer_with(&values_for(100), Priority::Interactive).unwrap();
    assert_eq!(outputs, golden_for(&net, &values_for(100)));
    for (i, mut ticket) in tickets {
        let resp = ticket.wait_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.outputs, golden_for(&net, &values_for(i)), "ticket {i}");
    }
    let stats = client.stats().unwrap();
    assert!(stats.contains("workers=4"), "{stats}");
    assert_eq!(pool_requests(&serving), 9);
    client.quit().unwrap();
    fe.stop();
}

/// A mock target that stashes every submission and completes the whole
/// backlog later in a shuffled order — the adversarial schedule for the
/// frontend's writer-side demux and the client's reply routing.  Requests
/// whose id is ≡ 3 (mod 5) fail with an engine error naming the id, so
/// error routing is exercised alongside success routing.
struct ShuffleTarget {
    next: AtomicU64,
    stash: Mutex<Vec<(RequestId, Vec<i32>, Priority, mpsc::Sender<Reply>)>>,
}

impl ShuffleTarget {
    fn new() -> Self {
        Self {
            next: AtomicU64::new(0),
            stash: Mutex::new(Vec::new()),
        }
    }

    fn stashed(&self) -> usize {
        self.stash.lock().unwrap().len()
    }

    /// Complete every stashed request in a seed-shuffled order.
    fn complete_shuffled(&self, seed: u64) {
        let mut stash: Vec<_> = self.stash.lock().unwrap().drain(..).collect();
        let mut rng = Xoshiro256::seed_from_u64(seed);
        for i in (1..stash.len()).rev() {
            stash.swap(i, rng.index(i + 1));
        }
        for (id, input, priority, reply) in stash {
            let result = if id % 5 == 3 {
                Err(InferError(format!("boom {id}")))
            } else {
                Ok(Response {
                    id,
                    // echo the input; encode the scheduled class so the
                    // client can assert the priority rode the wire
                    output: input,
                    class: match priority {
                        Priority::Interactive => 1,
                        Priority::Bulk => 2,
                    },
                    queue_seconds: 0.0,
                    compute_seconds: 0.0,
                    batch_occupancy: 1,
                })
            };
            let _ = reply.send(Reply { id, result });
        }
    }
}

impl SubmitTarget for ShuffleTarget {
    fn submit_with(
        &self,
        input: Vec<i32>,
        priority: Priority,
        _deadline: Option<Instant>,
        reply: mpsc::Sender<Reply>,
    ) -> Result<RequestId> {
        let id = self.next.fetch_add(1, Ordering::SeqCst);
        self.stash.lock().unwrap().push((id, input, priority, reply));
        Ok(id)
    }

    fn stats(&self) -> StatsReport {
        StatsReport {
            requests: self.next.load(Ordering::SeqCst),
            batches: 0,
            rejected: 0,
            mean_latency_s: 0.0,
            p50_latency_s: 0.0,
            p95_latency_s: 0.0,
            p99_latency_s: 0.0,
            occupancy: 0.0,
            promoted: 0,
            throughput: 0.0,
            throughput_10s: 0.0,
            workers: 1,
            shed: 0,
            autoscale_spawns: 0,
            autoscale_parks: 0,
        }
    }
}

/// The demux property: random out-of-order completion orders across
/// random priority mixes must match tickets to replies exactly once —
/// right payload, right class, engine errors on exactly the failing ids —
/// and leave nothing stashed or pending afterwards.
#[test]
fn prop_demux_matches_tickets_exactly_once_under_shuffled_completions() {
    prop_check(8, |g| {
        let target = Arc::new(ShuffleTarget::new());
        let fe = NetFrontend::start("127.0.0.1:0", target.clone()).unwrap();
        let mut client = NetClient::connect(&fe.addr()).unwrap();
        let n = g.usize(1..25);
        let mut tickets = Vec::new();
        for i in 0..n {
            let prio = if g.bool(0.5) {
                Priority::Interactive
            } else {
                Priority::Bulk
            };
            // 4 values are enough: the mock echoes, it never validates
            let vals = [i as f32, 0.25, -0.5, 0.125];
            tickets.push((i, prio, vals, client.submit(&vals, prio).unwrap()));
        }
        // submissions flow through the connection's reader thread: wait
        // for the mock to hold all of them before completing the backlog
        let deadline = Instant::now() + Duration::from_secs(10);
        while target.stashed() < n {
            if Instant::now() > deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        target.complete_shuffled(g.u64(0..=u64::MAX / 2));
        let mut ok = true;
        for (i, prio, vals, mut ticket) in tickets {
            // one client on one connection: the mock's ids are assigned in
            // line order, so id == submission index i
            if i % 5 == 3 {
                match ticket.wait_timeout(Duration::from_secs(10)) {
                    Err(e) => ok &= e.to_string().contains(&format!("boom {i}")),
                    Ok(_) => return false,
                }
            } else {
                match ticket.wait_timeout(Duration::from_secs(10)) {
                    Ok(resp) => {
                        ok &= resp.outputs == zynq_dnn::fixedpoint::quantize_slice(&vals);
                        ok &= resp.class
                            == match prio {
                                Priority::Interactive => 1,
                                Priority::Bulk => 2,
                            };
                        // exactly once: no second reply hiding behind it
                        ok &= ticket.try_wait().is_err();
                    }
                    Err(_) => return false,
                }
            }
        }
        client.quit().unwrap();
        fe.stop();
        ok && target.stashed() == 0
    });
}

/// Dropping a client mid-pipeline must leak nothing: the in-flight
/// requests still execute and release their slots server-side, new
/// connections keep being served, and the frontend's stop() returns
/// (bounded demux join).
#[test]
fn connection_drop_mid_pipeline_leaks_nothing() {
    let (fe, serving, net) = start_stack(4, 4);
    {
        let mut client = NetClient::connect(&fe.addr()).unwrap();
        let mut abandoned = Vec::new();
        for i in 0..32usize {
            abandoned.push(client.submit(&values_for(i), Priority::Bulk).unwrap());
        }
        // neither waited nor QUIT: the socket just goes away
        drop(abandoned);
        drop(client);
    }
    // every abandoned request still completes server-side (slots released,
    // metrics counted) — poll the merged snapshot up to a bounded deadline
    let deadline = Instant::now() + Duration::from_secs(10);
    while pool_requests(&serving) < 32 {
        assert!(Instant::now() < deadline, "abandoned requests never completed");
        std::thread::sleep(Duration::from_millis(5));
    }
    // the pool has capacity again and the frontend still serves
    let mut c2 = NetClient::connect(&fe.addr()).unwrap();
    c2.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let (_, outputs) = c2.infer(&values_for(500)).unwrap();
    assert_eq!(outputs, golden_for(&net, &values_for(500)));
    assert_eq!(pool_requests(&serving), 33);
    c2.quit().unwrap();
    fe.stop(); // must return: demux threads exited with their connections
}

/// Submission errors are ticket-scoped on the wire: a wrong-width tagged
/// request gets `ERR #<tag>` routed to exactly its ticket, and both wire
/// forms keep working on the connection afterwards.
#[test]
fn submit_errors_are_ticket_scoped() {
    let (fe, _serving, net) = start_stack(4, 4);
    let mut client = NetClient::connect(&fe.addr()).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut good_before = client.submit(&values_for(1), Priority::Bulk).unwrap();
    let mut bad = client.submit(&[1.0, 2.0, 3.0], Priority::Interactive).unwrap();
    let mut good_after = client.submit(&values_for(2), Priority::Interactive).unwrap();
    let e = bad.wait_timeout(Duration::from_secs(10)).unwrap_err();
    assert!(e.to_string().contains("input width"), "{e}");
    let resp = good_before.wait_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(resp.outputs, golden_for(&net, &values_for(1)));
    let resp = good_after.wait_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(resp.outputs, golden_for(&net, &values_for(2)));
    let (_, outputs) = client.infer(&values_for(3)).unwrap();
    assert_eq!(outputs, golden_for(&net, &values_for(3)));
    client.quit().unwrap();
    fe.stop();
}
