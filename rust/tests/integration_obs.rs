//! Integration: the PR 7 observability surface over a real TCP socket —
//! `STATS JSON` / `STATS PROM` exports and `TRACE` queries against the
//! sharded pool behind the frontend.
//!
//! What this locks in:
//!
//! * served requests produce complete, monotonically ordered span
//!   timelines queryable via `TRACE LAST <n>` and `TRACE #<id>`,
//! * `STATS JSON` round-trips through the crate's own JSON parser with
//!   the windowed throughput gauge alongside the lifetime one,
//! * `STATS PROM` frames a Prometheus-style exposition with `# EOF`,
//! * `trace_sample = 0` disables the ring: queries answer honestly
//!   (`TRACES 0`, `ERR trace ...`) instead of guessing.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use zynq_dnn::bench::random_qnet;
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::{EngineFactory, NetFrontend};
use zynq_dnn::nn::spec::quickstart;
use zynq_dnn::serve::{start_serving, Serving};

fn start_stack(trace_sample: u64) -> (NetFrontend, Arc<Serving>) {
    let net = random_qnet(&quickstart(), 0x0B5);
    let factory = EngineFactory {
        backend: "native".into(),
        batch: 2,
        net,
        artifacts_dir: zynq_dnn::runtime::default_artifacts_dir(),
        native_threads: 1,
        sparse_threshold: None,
        artifact: None,
    };
    let cfg = ServerConfig {
        workers: 2,
        batch: 2,
        batch_deadline_us: 300,
        queue_depth: 1024,
        trace_sample,
        ..Default::default()
    };
    let serving = Arc::new(start_serving(&cfg, factory).unwrap());
    let fe = NetFrontend::start("127.0.0.1:0", serving.clone()).unwrap();
    (fe, serving)
}

struct Wire {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Wire {
    fn connect(addr: &std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Wire { reader, writer: stream }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line.trim_end().to_string()
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.send(line);
        self.recv()
    }
}

fn infer_line(seed: usize) -> String {
    let vals: Vec<String> = (0..64)
        .map(|k| format!("{}", ((k * 7 + seed * 13) % 101) as f32 / 101.0 - 0.5))
        .collect();
    format!("INFER {}", vals.join(" "))
}

/// Parse every `<name>_us=<v>` field of a trace line; `-` is an error
/// here because the requests below all completed before the query.
fn span_offsets_us(trace_line: &str) -> Vec<(String, f64)> {
    trace_line
        .split_whitespace()
        .filter_map(|tok| tok.split_once("_us="))
        .map(|(name, v)| {
            let us: f64 = v.parse().unwrap_or_else(|_| {
                panic!("span {name} not stamped in {trace_line:?}")
            });
            (name.to_string(), us)
        })
        .collect()
}

#[test]
fn trace_and_stats_round_trip_over_tcp() {
    let (fe, _serving) = start_stack(1);
    let mut wire = Wire::connect(&fe.addr());

    let total = 6usize;
    for i in 0..total {
        let reply = wire.roundtrip(&infer_line(i));
        assert!(reply.starts_with("OK "), "lockstep reply: {reply}");
    }

    // classic STATS grew the windowed gauge, append-only
    let stats = wire.roundtrip("STATS");
    assert!(stats.contains("win_throughput="), "{stats}");

    // STATS JSON round-trips through the crate's own parser
    let json_line = wire.roundtrip("STATS JSON");
    let json = zynq_dnn::config::json::parse(&json_line).unwrap();
    let requests = json.get("requests").unwrap().as_f64().unwrap();
    assert_eq!(requests, total as f64, "{json_line}");
    assert!(json.get("throughput_10s").is_some(), "{json_line}");
    assert_eq!(json.get("workers").unwrap().as_f64().unwrap(), 2.0);

    // STATS PROM: read until the `# EOF` frame
    wire.send("STATS PROM");
    let mut prom = Vec::new();
    loop {
        let line = wire.recv();
        if line == "# EOF" {
            break;
        }
        prom.push(line);
    }
    assert!(
        prom.iter().any(|l| l.starts_with("zdnn_requests_total ")),
        "{prom:?}"
    );
    assert!(
        prom.iter().any(|l| l.starts_with("# TYPE zdnn_throughput_10s gauge")),
        "{prom:?}"
    );
    assert!(
        prom.iter().any(|l| l.starts_with("zdnn_traces_recorded_total ")),
        "{prom:?}"
    );

    // TRACE LAST: every line is a complete, ordered timeline
    wire.send(&format!("TRACE LAST {total}"));
    let header = wire.recv();
    let k: usize = header
        .strip_prefix("TRACES ")
        .unwrap_or_else(|| panic!("bad header {header:?}"))
        .parse()
        .unwrap();
    assert_eq!(k, total, "ring holds every request (capacity 1024 > {total})");
    let mut some_id = None;
    for _ in 0..k {
        let line = wire.recv();
        assert!(line.starts_with("TRACE #"), "{line}");
        let id: u64 = line[7..].split_whitespace().next().unwrap().parse().unwrap();
        some_id = Some(id);
        let spans = span_offsets_us(&line);
        let names: Vec<&str> = spans.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            ["submitted", "enqueued", "batch_formed", "execute_start", "execute_end", "reply_sent"],
            "{line}"
        );
        for w in spans.windows(2) {
            assert!(
                w[1].1 >= w[0].1,
                "spans out of order: {} ({}) before {} ({}) in {line}",
                w[0].0, w[0].1, w[1].0, w[1].1
            );
        }
    }

    // single-id round trip with an id the server just reported
    let id = some_id.unwrap();
    let one = wire.roundtrip(&format!("TRACE #{id}"));
    assert!(one.starts_with(&format!("TRACE #{id} ")), "{one}");

    // unknown id answers honestly
    let missing = wire.roundtrip("TRACE #999999");
    assert!(missing.starts_with("ERR trace #999999"), "{missing}");

    wire.send("QUIT");
    fe.stop();
}

#[test]
fn trace_sample_zero_disables_the_ring() {
    let (fe, _serving) = start_stack(0);
    let mut wire = Wire::connect(&fe.addr());
    let reply = wire.roundtrip(&infer_line(0));
    assert!(reply.starts_with("OK "), "{reply}");

    wire.send("TRACE LAST 5");
    assert_eq!(wire.recv(), "TRACES 0");
    let missing = wire.roundtrip("TRACE #0");
    assert!(missing.starts_with("ERR trace #0"), "{missing}");

    // exports still work with tracing off
    let json_line = wire.roundtrip("STATS JSON");
    assert!(zynq_dnn::config::json::parse(&json_line).is_ok(), "{json_line}");

    wire.send("QUIT");
    fe.stop();
}
