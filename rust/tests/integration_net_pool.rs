//! Integration: the TCP line-protocol frontend over the sharded serving
//! pool — `serve --listen --workers 4` equivalent, driven loopback.
//!
//! What this locks in (the PR 4 acceptance surface):
//!
//! * remote traffic reaches the pool at all (the frontend used to bail on
//!   `--workers > 1`),
//! * mixed `INFER` / `INFER BULK` lines get exactly one reply each, with
//!   outputs bit-identical to the golden forward,
//! * malformed lines get `ERR` and the connection stays usable,
//! * bulk traffic completes under an interactive flood (the aging
//!   property, observed end-to-end through the socket),
//! * `STATS` reports the *merged* pool snapshot (workers=N, promotions,
//!   p50/p95/p99), not a single engine's view.

use std::sync::Arc;
use std::time::Duration;

use zynq_dnn::bench::random_qnet;
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::{EngineFactory, NetClient, NetFrontend, Priority};
use zynq_dnn::nn::forward_q;
use zynq_dnn::nn::spec::quickstart;
use zynq_dnn::serve::{start_serving, Serving};
use zynq_dnn::tensor::MatI;

fn start_stack(
    workers: usize,
    batch: usize,
    promote_us: u64,
) -> (NetFrontend, Arc<Serving>, zynq_dnn::nn::QNetwork) {
    let net = random_qnet(&quickstart(), 0xB0);
    let factory = EngineFactory {
        backend: "native".into(),
        batch,
        net: net.clone(),
        artifacts_dir: zynq_dnn::runtime::default_artifacts_dir(),
        native_threads: 1,
        sparse_threshold: None,
        artifact: None,
    };
    let cfg = ServerConfig {
        workers,
        batch,
        batch_deadline_us: 300,
        bulk_promote_us: promote_us,
        queue_depth: 4096,
        ..Default::default()
    };
    let serving = Arc::new(start_serving(&cfg, factory).unwrap());
    let fe = NetFrontend::start("127.0.0.1:0", serving.clone()).unwrap();
    (fe, serving, net)
}

fn values_for(seed: usize) -> Vec<f32> {
    (0..64)
        .map(|k| ((k * 7 + seed * 13) % 101) as f32 / 101.0 - 0.5)
        .collect()
}

fn golden_for(net: &zynq_dnn::nn::QNetwork, values: &[f32]) -> (usize, Vec<i32>) {
    let xq = zynq_dnn::fixedpoint::quantize_slice(values);
    let y = forward_q(net, &MatI::from_vec(1, 64, xq)).unwrap();
    let class = zynq_dnn::nn::forward::argmax_rows(&y)[0];
    (class, y.row(0).to_vec())
}

fn pool_snapshot(serving: &Serving) -> zynq_dnn::serve::PoolSnapshot {
    match serving {
        Serving::Pool(p) => p.snapshot(),
        Serving::Single(_) => panic!("expected a pool"),
    }
}

/// Mixed-priority traffic from concurrent TCP clients over a 4-worker
/// pool: every line gets exactly one `OK` reply with the golden output,
/// and the merged metrics count every request exactly once.
#[test]
fn mixed_priorities_exactly_once_over_tcp() {
    let (fe, serving, net) = start_stack(4, 4, 20_000);
    let addr = fe.addr();
    let net = Arc::new(net);
    let mut handles = Vec::new();
    for t in 0..3usize {
        let net = net.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = NetClient::connect(&addr).unwrap();
            c.set_timeout(Some(Duration::from_secs(30))).unwrap();
            for i in 0..20usize {
                let vals = values_for(t * 100 + i);
                let prio = if i % 2 == 0 {
                    Priority::Interactive
                } else {
                    Priority::Bulk
                };
                let (class, outputs) = c.infer_with(&vals, prio).unwrap();
                let (want_class, want_out) = golden_for(&net, &vals);
                assert_eq!(outputs, want_out, "client {t} request {i}");
                assert_eq!(class, want_class, "client {t} request {i}");
            }
            c.quit().unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = pool_snapshot(&serving);
    assert_eq!(snap.aggregate.requests, 60, "exactly-once accounting");
    assert_eq!(snap.aggregate.occupied_slots, 60);
    assert_eq!(snap.aggregate.interactive_requests, 30);
    assert_eq!(snap.aggregate.bulk_requests, 30);
    assert_eq!(snap.shards.len(), 4);
    fe.stop();
}

/// Malformed input gets `ERR` (not a dropped connection, not a crash) on
/// the pool-backed frontend, and valid traffic keeps flowing after.
#[test]
fn malformed_lines_get_err_and_connection_survives() {
    let (fe, serving, net) = start_stack(4, 4, 20_000);
    // a bare socket, so malformed lines NetClient would never emit can go
    // down the wire verbatim
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(fe.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut round_trip = move |line: &str| -> String {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    };
    assert!(round_trip("FROBNICATE").starts_with("ERR"));
    assert!(round_trip("INFER").starts_with("ERR"));
    assert!(round_trip("INFER BULK").starts_with("ERR"));
    assert!(round_trip("INFER BULK notanumber").starts_with("ERR"));
    assert!(round_trip("INFER 1 2 3").starts_with("ERR"), "wrong width");
    // the same connection still serves valid lines afterwards
    let vals = values_for(7);
    let (class, outputs) = {
        let mut line = String::from("INFER BULK");
        for v in &vals {
            line.push(' ');
            line.push_str(&v.to_string());
        }
        let reply = round_trip(&line);
        assert!(reply.starts_with("OK "), "{reply}");
        let parts: Vec<&str> = reply.split_ascii_whitespace().collect();
        let class: usize = parts[1].parse().unwrap();
        let outputs: Vec<i32> = parts[5..].iter().map(|s| s.parse().unwrap()).collect();
        (class, outputs)
    };
    let (want_class, want_out) = golden_for(&net, &vals);
    assert_eq!(outputs, want_out);
    assert_eq!(class, want_class);
    // parse errors never reach the pool; the one valid request did
    let snap = pool_snapshot(&serving);
    assert_eq!(snap.aggregate.requests, 1);
    assert!(round_trip("QUIT").is_empty(), "QUIT closes without a reply");
    fe.stop();
}

/// Bulk traffic must complete (exactly once, correct outputs) while
/// interactive floods arrive on other connections — the two-level queue's
/// no-starvation property, observed through the socket.  The promotion
/// threshold is set low so aging is live during the flood.
#[test]
fn bulk_completes_under_interactive_flood() {
    let (fe, serving, net) = start_stack(4, 4, 500);
    let addr = fe.addr();
    let net = Arc::new(net);
    let mut flood = Vec::new();
    for t in 0..4usize {
        flood.push(std::thread::spawn(move || {
            let mut c = NetClient::connect(&addr).unwrap();
            c.set_timeout(Some(Duration::from_secs(30))).unwrap();
            for i in 0..60usize {
                let vals = values_for(t * 1000 + i);
                c.infer_with(&vals, Priority::Interactive).unwrap();
            }
            c.quit().unwrap();
        }));
    }
    // the bulk client runs concurrently with the flood; a starved request
    // would trip the 10 s reply timeout instead of hanging the test
    let bulk_net = net.clone();
    let bulk = std::thread::spawn(move || {
        let mut c = NetClient::connect(&addr).unwrap();
        c.set_timeout(Some(Duration::from_secs(10))).unwrap();
        for i in 0..30usize {
            let vals = values_for(5000 + i);
            let (class, outputs) = c
                .infer_with(&vals, Priority::Bulk)
                .unwrap_or_else(|e| panic!("bulk request {i} starved: {e}"));
            let (want_class, want_out) = golden_for(&bulk_net, &vals);
            assert_eq!(outputs, want_out, "bulk request {i}");
            assert_eq!(class, want_class, "bulk request {i}");
        }
        c.quit().unwrap();
    });
    for h in flood {
        h.join().unwrap();
    }
    bulk.join().unwrap();
    let snap = pool_snapshot(&serving);
    assert_eq!(snap.aggregate.bulk_requests, 30, "every bulk request served");
    assert_eq!(snap.aggregate.interactive_requests, 240);
    fe.stop();
}

/// `STATS` over a pool-backed frontend reports the merged per-shard
/// snapshot with the uniform key set.
#[test]
fn stats_reports_merged_pool_snapshot() {
    let (fe, serving, _net) = start_stack(4, 2, 20_000);
    let mut c = NetClient::connect(&fe.addr()).unwrap();
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    for i in 0..12usize {
        let prio = if i % 3 == 0 {
            Priority::Interactive
        } else {
            Priority::Bulk
        };
        c.infer_with(&values_for(i), prio).unwrap();
    }
    let stats = c.stats().unwrap();
    assert!(stats.starts_with("STATS requests=12 "), "{stats}");
    assert!(stats.contains("workers=4"), "{stats}");
    for key in [
        "batches=",
        "rejected=",
        "mean_latency_us=",
        "p50_latency_us=",
        "p95_latency_us=",
        "p99_latency_us=",
        "occupancy=",
        "promoted=",
        "throughput=",
    ] {
        assert!(stats.contains(key), "missing {key} in {stats}");
    }
    // the wire line matches the in-process merged snapshot
    let snap = pool_snapshot(&serving);
    assert_eq!(snap.aggregate.requests, 12);
    c.quit().unwrap();
    fe.stop();
}

/// The same frontend still fronts a single-engine stack (`--workers 1`)
/// through the `Serving` delegator, bulk lines included.
#[test]
fn single_worker_stack_behind_same_frontend() {
    let (fe, serving, net) = start_stack(1, 4, 20_000);
    assert!(matches!(&*serving, Serving::Single(_)));
    let mut c = NetClient::connect(&fe.addr()).unwrap();
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let vals = values_for(42);
    let (class, outputs) = c.infer_with(&vals, Priority::Bulk).unwrap();
    let (want_class, want_out) = golden_for(&net, &vals);
    assert_eq!(outputs, want_out);
    assert_eq!(class, want_class);
    let stats = c.stats().unwrap();
    assert!(stats.contains("workers=1"), "{stats}");
    c.quit().unwrap();
    fe.stop();
}
