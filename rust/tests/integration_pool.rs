//! Integration: the sharded serving pool end to end — every submitted
//! request is answered exactly once across shard counts {1, 2, 4} and all
//! selection policies, with outputs bit-identical to the golden forward.

use std::time::Duration;

use zynq_dnn::bench::random_qnet;
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::EngineFactory;
use zynq_dnn::nn::forward_q;
use zynq_dnn::nn::spec::{har_4, quickstart};
use zynq_dnn::coordinator::{SubmitOptions, SubmitTarget};
use zynq_dnn::serve::{Priority, ServePool};
use zynq_dnn::tensor::MatI;
use zynq_dnn::util::prop::prop_check;
use zynq_dnn::util::rng::Xoshiro256;

fn factory(batch: usize) -> EngineFactory {
    EngineFactory {
        backend: "native".into(),
        batch,
        net: random_qnet(&quickstart(), 0xF00),
        artifacts_dir: zynq_dnn::runtime::default_artifacts_dir(),
        native_threads: 1,
        sparse_threshold: None,
        artifact: None,
    }
}

fn config(workers: usize, batch: usize, policy: &str) -> ServerConfig {
    ServerConfig {
        workers,
        batch,
        policy: policy.into(),
        batch_deadline_us: 300,
        bulk_promote_us: 2_000,
        queue_depth: 4096,
        ..Default::default()
    }
}

fn rand_input(rng: &mut Xoshiro256) -> Vec<i32> {
    (0..64)
        .map(|_| zynq_dnn::fixedpoint::quantize(rng.uniform(-1.0, 1.0)))
        .collect()
}

/// The ISSUE-level delivery guarantee: across shard counts {1, 2, 4},
/// random batch sizes, policies, and priority mixes, every submitted
/// request receives exactly one response, with the right id and the
/// golden output.
#[test]
fn prop_exactly_one_response_across_shard_counts() {
    for &workers in &[1usize, 2, 4] {
        prop_check(4, |g| {
            let batch = g.usize(1..6);
            let policy = ["round-robin", "least-loaded", "p2c"][g.usize(0..3)];
            let n_requests = g.usize(1..40);
            let f = factory(batch);
            let net = f.net.clone();
            let pool = ServePool::start(&config(workers, batch, policy), f).unwrap();
            let mut rng = Xoshiro256::seed_from_u64(g.u64(0..=u64::MAX / 2));
            let mut pairs = Vec::new();
            for _ in 0..n_requests {
                let input = rand_input(&mut rng);
                let prio = if g.bool(0.3) {
                    Priority::Interactive
                } else {
                    Priority::Bulk
                };
                let opts = SubmitOptions::with_priority(prio);
                let ticket = pool.submit(input.clone(), opts).unwrap();
                pairs.push((input, ticket));
            }
            for (input, mut ticket) in pairs {
                let resp = match ticket.wait_timeout(Duration::from_secs(10)) {
                    Ok(r) => r,
                    // a lost or failed request = starvation/drop
                    Err(_) => return false,
                };
                if resp.id != ticket.id() {
                    return false;
                }
                let want = forward_q(&net, &MatI::from_vec(1, 64, input)).unwrap();
                if resp.output != want.row(0) {
                    return false;
                }
                // exactly once: a second wait must be AlreadyCompleted,
                // never another reply
                if ticket.try_wait().is_ok() {
                    return false;
                }
            }
            let snap = pool.snapshot();
            pool.shutdown().unwrap();
            // no duplicate or phantom deliveries in the metrics either
            snap.aggregate.requests == n_requests as u64
                && snap.shards.len() == workers
                && snap.aggregate.occupied_slots == n_requests as u64
        });
    }
}

/// Shutdown with a deep backlog must not lose requests on any shard
/// (multi-batch forced drains).
#[test]
fn shutdown_drains_backlog_on_every_shard() {
    let pool = ServePool::start(
        &ServerConfig {
            workers: 4,
            batch: 4,
            batch_deadline_us: 1_000_000,
            queue_depth: 4096,
            ..Default::default()
        },
        factory(4),
    )
    .unwrap();
    let mut rng = Xoshiro256::seed_from_u64(7);
    let tickets: Vec<_> = (0..66)
        .map(|i| {
            let prio = if i % 2 == 0 {
                Priority::Interactive
            } else {
                Priority::Bulk
            };
            let opts = SubmitOptions::with_priority(prio);
            pool.submit(rand_input(&mut rng), opts).unwrap()
        })
        .collect();
    pool.shutdown().unwrap();
    for (i, mut t) in tickets.into_iter().enumerate() {
        assert!(
            t.wait_timeout(Duration::from_secs(1)).is_ok(),
            "request {i} lost in shutdown drain"
        );
    }
}

/// Interactive requests must see a better p99 than bulk under a backlog on
/// the pool (the two-level queue working end to end).
#[test]
fn interactive_tail_beats_bulk_under_backlog() {
    if std::env::var("ZDNN_SKIP_PERF").map(|v| v == "1").unwrap_or(false) {
        eprintln!("skipping: ZDNN_SKIP_PERF=1");
        return;
    }
    // HAR-sized layers so the backlog drains over ~100 ms, not µs — the
    // two queues' tails must land in clearly different latency buckets
    let f = EngineFactory {
        backend: "native".into(),
        batch: 8,
        net: random_qnet(&har_4(), 0xF01),
        artifacts_dir: zynq_dnn::runtime::default_artifacts_dir(),
        native_threads: 1,
        sparse_threshold: None,
        artifact: None,
    };
    let s_in = f.net.spec.inputs();
    let pool = ServePool::start(
        &ServerConfig {
            workers: 2,
            batch: 8,
            batch_deadline_us: 200,
            bulk_promote_us: 5_000_000, // no promotion inside this test
            queue_depth: 4096,
            ..Default::default()
        },
        f,
    )
    .unwrap();
    let mut rng = Xoshiro256::seed_from_u64(8);
    // burst far beyond one batch so a backlog forms; 1 in 4 interactive
    let mut tickets: Vec<_> = (0..400)
        .map(|i| {
            let prio = if i % 4 == 0 {
                Priority::Interactive
            } else {
                Priority::Bulk
            };
            let input: Vec<i32> = (0..s_in)
                .map(|_| zynq_dnn::fixedpoint::quantize(rng.uniform(-1.0, 1.0)))
                .collect();
            pool.submit(input, SubmitOptions::with_priority(prio)).unwrap()
        })
        .collect();
    for t in tickets.iter_mut() {
        t.wait_timeout(Duration::from_secs(10)).unwrap();
    }
    let agg = pool.snapshot().aggregate;
    assert_eq!(agg.interactive_requests, 100);
    assert_eq!(agg.bulk_requests, 300);
    assert!(
        agg.interactive_p99_s < agg.bulk_p99_s,
        "interactive p99 {} must beat bulk p99 {} under backlog",
        agg.interactive_p99_s,
        agg.bulk_p99_s
    );
    pool.shutdown().unwrap();
}
