//! Integration: the full serving stack — coordinator + batcher + engines —
//! including the PJRT backend on the real artifacts, cross-backend
//! bit-equality through the server, and an end-to-end accuracy run.

use std::time::Duration;

use zynq_dnn::bench::random_qnet;
use zynq_dnn::config::ServerConfig;
use zynq_dnn::coordinator::{EngineFactory, Server, SubmitOptions, SubmitTarget};
use zynq_dnn::data::har;
use zynq_dnn::nn::spec::{har_4, quickstart};
use zynq_dnn::runtime::default_artifacts_dir;
use zynq_dnn::train::{TrainConfig, Trainer};
use zynq_dnn::util::rng::Xoshiro256;

fn have_artifacts() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn factory(backend: &str, batch: usize, net: zynq_dnn::nn::QNetwork) -> EngineFactory {
    EngineFactory {
        backend: backend.into(),
        batch,
        net,
        artifacts_dir: default_artifacts_dir(),
        native_threads: 1,
        sparse_threshold: None,
        artifact: None,
    }
}

fn config(batch: usize, backend: &str) -> ServerConfig {
    ServerConfig {
        batch,
        backend: backend.into(),
        batch_deadline_us: 500,
        ..Default::default()
    }
}

fn rand_inputs(n: usize, width: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            (0..width)
                .map(|_| zynq_dnn::fixedpoint::quantize(rng.uniform(-1.0, 1.0)))
                .collect()
        })
        .collect()
}

#[test]
fn all_backends_serve_identical_outputs() {
    // serve a *pruned* net so native-sparse exercises real sparsity; the
    // pjrt backend joins only when its AOT artifacts are built
    let net = zynq_dnn::sim::pruning::prune_qnetwork(&random_qnet(&quickstart(), 0x90), 0.85);
    let inputs = rand_inputs(12, 64, 0x91);
    let mut backends = vec!["native", "native-sparse", "sim-batch", "sim-prune"];
    if have_artifacts() {
        backends.push("pjrt");
    } else {
        eprintln!("skipping pjrt backend: artifacts not built (run `make artifacts`)");
    }
    let mut reference: Option<Vec<Vec<i32>>> = None;
    for backend in backends {
        let server = Server::start(&config(4, backend), factory(backend, 4, net.clone())).unwrap();
        let tickets = server.submit_many(inputs.clone(), SubmitOptions::default()).unwrap();
        let outs: Vec<Vec<i32>> = tickets
            .into_iter()
            .map(|mut t| t.wait_timeout(Duration::from_secs(30)).unwrap().output)
            .collect();
        match &reference {
            None => reference = Some(outs),
            Some(want) => assert_eq!(&outs, want, "{backend} diverges"),
        }
        server.shutdown().unwrap();
    }
}

#[test]
fn pjrt_served_accuracy_matches_direct_eval() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    // train a small HAR-4 quickly, then serve the test set through PJRT
    let train = har::generate(400, 1);
    let test = har::generate(120, 2);
    let mut trainer = Trainer::new(har_4(), 3);
    trainer
        .fit(
            &train,
            &TrainConfig {
                epochs: 3,
                ..Default::default()
            },
        )
        .unwrap();
    let nw = trainer.to_weights();
    let direct = zynq_dnn::train::evaluate_q(&nw, &test);

    let server =
        Server::start(&config(4, "pjrt"), factory("pjrt", 4, nw.quantized())).unwrap();
    let mut correct = 0;
    let tickets: Vec<_> = (0..test.len())
        .map(|i| {
            let input = zynq_dnn::fixedpoint::quantize_slice(test.x.row(i));
            server.submit(input, SubmitOptions::default()).unwrap()
        })
        .collect();
    for (i, mut t) in tickets.into_iter().enumerate() {
        let resp = t.wait_timeout(Duration::from_secs(30)).unwrap();
        if resp.class == test.y[i] {
            correct += 1;
        }
    }
    let served = correct as f64 / test.len() as f64;
    // direct eval scores identity-requantized logits; the served path
    // classifies the Q7.8 *sigmoid* outputs, which can tie when several
    // logits saturate |z| >= 5 — allow only that small artifact
    assert!(
        served >= direct - 0.05 && served <= direct + 1e-9,
        "served accuracy {served} vs direct {direct}"
    );
    server.shutdown().unwrap();
}

#[test]
fn metrics_reflect_served_traffic() {
    let net = random_qnet(&quickstart(), 0x92);
    let server = Server::start(&config(4, "native"), factory("native", 4, net)).unwrap();
    let inputs = rand_inputs(17, 64, 0x93);
    let tickets = server.submit_many(inputs, SubmitOptions::default()).unwrap();
    for mut t in tickets {
        t.wait_timeout(Duration::from_secs(10)).unwrap();
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 17);
    assert!(snap.batches >= 5, "17 requests / batch 4 -> >=5 batches");
    assert!(snap.occupancy > 0.5);
    assert!(snap.mean_latency_s > 0.0);
    server.shutdown().unwrap();
}

#[test]
fn sim_backend_reports_accelerator_time_not_wallclock() {
    let net = random_qnet(&quickstart(), 0x94);
    let server =
        Server::start(&config(2, "sim-batch"), factory("sim-batch", 2, net)).unwrap();
    let inputs = rand_inputs(4, 64, 0x95);
    let tickets = server.submit_many(inputs, SubmitOptions::default()).unwrap();
    for mut t in tickets {
        let resp = t.wait_timeout(Duration::from_secs(10)).unwrap();
        // quickstart on the simulated ZedBoard: hundreds of µs, far above
        // the host's wall-clock for the same tiny net — proves the sim
        // time is being reported
        assert!(
            resp.compute_seconds > 50e-6,
            "expected simulated seconds, got {}",
            resp.compute_seconds
        );
    }
    server.shutdown().unwrap();
}
